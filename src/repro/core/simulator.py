"""Trace-driven cold-start simulator engines (Section 5.1 of the paper).

The public front door lives in :mod:`repro.core.experiment` —
``run(trace, spec)`` / ``sweep(trace, specs)`` over declarative
:class:`~repro.core.experiment.PolicySpec` grids. This module holds the
engines those drive, all computing their decisions through the
single-source policy math in :mod:`repro.core.policy_math`:

  * :func:`simulate_scalar` — event-driven reference. Walks each app's
    invocation sequence, querying any :class:`repro.core.policy.Policy`
    (including the full hybrid policy with its ARIMA path). This is the
    float64 oracle and handles arbitrary policies.

  * the vectorized sweep engines (:func:`_run_fixed_sweep` /
    :func:`_run_hybrid_sweep`): all apps advance together through a
    ``lax.scan`` over padded event indices, and S stacked policy
    configurations advance together along a *traced config axis* — the
    trace is bucketed, chunked, rebased and scanned ONCE for the whole
    grid. The hybrid scan is factored (see
    :class:`repro.core.policy_math.HybridSweepBlock`): histogram
    sufficient statistics are carried once per distinct histogram shape,
    percentile windows / gates once per distinct variant, so a
    CV-threshold grid pays one histogram update per step, not S. Apps are
    bucketed by event count so a handful of very chatty apps do not
    inflate the scan length for everyone, and each bucket is chunked over
    apps with double-buffered host→device transfer so ~1M-app traces fit
    in device memory. ARIMA cannot run inside a scan; apps whose
    out-of-bounds fraction crosses the threshold are re-simulated through
    the scalar engine per config and their results overridden (the paper:
    these are ~0.7% of invocations).

  * On TPU the sweep step runs as a Pallas kernel
    (:func:`repro.kernels.histogram.fused_hybrid_sweep_step_pallas`) in
    float32, with the per-config knobs delivered as an SMEM config block
    via scalar prefetch; ``engine="pallas"`` exercises it in interpret
    mode elsewhere.

  * ``simulate_hybrid_batch_reference`` — the pre-fused batched engine
    (per-step full-matrix cumsum), kept as the regression baseline for the
    ``benchmarks/policy_overhead.py`` step-throughput comparison
    (``engine="reference"``).

Float32 exactness (the TPU story): TPUs have no float64, so the Pallas and
reference engines carry float32 time state. Absolute timestamps on a
multi-week trace (t ~ 2e4 minutes) cannot hold sub-minute inter-arrival
structure in float32, so both float32 engines *rebase* each app chunk before
the scan — every app's timestamps are shifted by its own first event (the
chunk's per-row minimum), computed in float64 on the host. Policy verdicts
are invariant under time translation (a property test guards this), so the
rebased scan reproduces the float64 oracle's cold counts exactly whenever
the rebased times are float32-representable; trailing waste is reconstructed
afterward in float64 from the un-rebased clock. The decision layer itself
(percentile thresholds, windows, CV gate) is dtype-invariant by construction
— see :mod:`repro.core.policy_math`.

Exactly as in the paper, function execution time is simulated as 0 (so idle
time == inter-arrival time) to account wasted memory time conservatively, and
the first invocation of every app is a cold start.

The legacy module-level ``simulate*`` entry points were removed after their
deprecation cycle (they raise an ``AttributeError`` pointing at
``experiment.run``); all code goes through ``experiment.run``/``sweep``.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from . import policy_math
from .histogram import HistogramConfig
from .policy import (FixedKeepAlivePolicy, HybridConfig, HybridHistogramPolicy,
                     Policy, is_warm, loaded_idle_time)
from .workload import Trace

__all__ = [
    "SimResult", "simulate_scalar", "BUCKET_EDGES", "DEFAULT_APP_CHUNK",
]

BUCKET_EDGES = (64, 512, 4096, 1 << 62)

# Apps per device-resident chunk of the hybrid scan: bounds the cumulative
# count state ([chunk, n_bins]) regardless of fleet size. Sweeps divide it
# by the config-axis length so total device state stays bounded too.
DEFAULT_APP_CHUNK = 131072
_MIN_AUTO_CHUNK = 4096


@dataclasses.dataclass
class SimResult:
    cold: np.ndarray            # [n_apps] cold-start counts
    invocations: np.ndarray     # [n_apps] invocation counts
    wasted_minutes: np.ndarray  # [n_apps] loaded-but-idle memory time
    # Final per-app policy windows (None for engines/paths that predate the
    # conformance harness; filled by all engines here).
    final_prewarm: Optional[np.ndarray] = None     # [n_apps] float64
    final_keep_alive: Optional[np.ndarray] = None  # [n_apps] float64

    @property
    def cold_pct(self) -> np.ndarray:
        return 100.0 * self.cold / np.maximum(self.invocations, 1)

    def cold_pct_percentile(self, q: float = 75.0) -> float:
        return float(np.percentile(self.cold_pct, q))

    @property
    def total_wasted(self) -> float:
        return float(self.wasted_minutes.sum())

    @property
    def always_cold_fraction(self) -> float:
        # Only apps that were actually invoked can be always-cold; apps with
        # zero invocations trivially satisfy cold >= invocations (0 >= 0) and
        # must not inflate the fraction (paper Fig. 12 counts invoked apps).
        invoked = self.invocations > 0
        if not invoked.any():
            return 0.0
        return float(np.mean(self.cold[invoked] >= self.invocations[invoked]))


# --------------------------------------------------------------------------
# Scalar reference engine
# --------------------------------------------------------------------------

def simulate_scalar(trace: Trace, policy: Policy,
                    include_trailing: bool = True,
                    app_indices: Optional[Sequence[int]] = None) -> SimResult:
    idx = range(trace.n_apps) if app_indices is None else app_indices
    n = trace.n_apps
    cold = np.zeros(n, np.int64)
    inv = np.zeros(n, np.int64)
    waste = np.zeros(n, np.float64)
    final_pre = np.zeros(n, np.float64)
    final_keep = np.zeros(n, np.float64)
    for i in idx:
        t = trace.events(i)
        app = trace.app_id(i)
        inv[i] = len(t)
        w = policy.windows(app)
        if len(t):
            cold[i] += 1  # first invocation is always cold
            w = policy.on_invocation(app, None)
            for k in range(1, len(t)):
                it = float(t[k]) - float(t[k - 1])  # exec time = 0 => IT == IAT
                if not is_warm(it, w):
                    cold[i] += 1
                waste[i] += loaded_idle_time(it, w)
                w = policy.on_invocation(app, it)
            if include_trailing:
                tail_gap = trace.duration_minutes - float(t[-1])
                waste[i] += loaded_idle_time(tail_gap, w) if tail_gap > 0 else 0.0
        final_pre[i], final_keep[i] = w.prewarm, w.keep_alive
    return SimResult(cold, inv, waste, final_pre, final_keep)


# --------------------------------------------------------------------------
# Vectorized JAX engines — fixed keep-alive family
# --------------------------------------------------------------------------

def _fixed_step(keep_alive, carry, t_now):
    # ``keep_alive`` is [S, 1]: S stacked configs broadcast against the [n]
    # time column; cold/waste carries are [S, n], the clock stays [n].
    prev_t, cold, waste = carry
    valid = jnp.isfinite(t_now)
    it = t_now - prev_t
    first = ~jnp.isfinite(prev_t)
    warm = policy_math.warm_from_bounds(it, 0.0, keep_alive)
    is_cold = valid & (first | ~warm)
    gap_waste = jnp.where(valid & ~first,
                          policy_math.idle_from_bounds(it, 0.0, keep_alive),
                          0.0)
    new_prev = jnp.where(valid, t_now, prev_t)
    return (new_prev, cold + is_cold, waste + gap_waste), None


@partial(jax.jit, static_argnums=(3,))
def _fixed_scan(times, keep_alive, duration, include_trailing: bool):
    """Scan one event-count bucket for S stacked keep-alive values.

    times: [n, width]; keep_alive: [S, 1] (traced — new grid points never
    retrace). Returns (cold [S, n], waste [S, n]).
    """
    n = times.shape[0]
    S = keep_alive.shape[0]
    tdtype = times.dtype
    init = (jnp.full((n,), -jnp.inf, tdtype),
            jnp.zeros((S, n), jnp.int32), jnp.zeros((S, n), tdtype))
    (last_t, cold, waste), _ = jax.lax.scan(
        partial(_fixed_step, keep_alive), init, times.T)
    if include_trailing:
        tail = jnp.maximum(duration - last_t, 0.0)
        waste = waste + jnp.where(
            jnp.isfinite(last_t),
            policy_math.idle_from_bounds(tail, 0.0, keep_alive), 0.0)
    return cold, waste


@partial(jax.jit, static_argnums=(3, 4))
def _fixed_scan_sharded(times, keep_alive, duration, include_trailing: bool,
                        mesh):
    """:func:`_fixed_scan` partitioned along the app axis of ``mesh``.

    Per-shard programs are row slices of the single-device scan (no
    collectives; keep-alive knobs and the duration replicate), so the
    concatenated outputs are bit-identical. The mesh is a hashable static:
    one compilation per (mesh, shapes), same as the unsharded path.
    """
    from ..distributed.scaleout import shard_along_apps
    fn = lambda ts, ks, dur: _fixed_scan(ts, ks, dur, include_trailing)
    return shard_along_apps(fn, mesh, (0, None, None), -1)(
        times, keep_alive, duration)


def _run_fixed_sweep(trace: Trace, keeps: Sequence[float],
                     include_trailing: bool = True, *,
                     padded=None, devices=None) -> dict:
    """S fixed keep-alive configs in one pass (``inf`` == never unload).

    float64 time state: two-week traces (t ~ 2e4 minutes) lose the
    sub-millisecond IAT bits in float32, flipping warm/cold verdicts
    exactly at the keep-alive boundary vs the scalar oracle.
    ``padded`` is the trace's precomputed ``to_padded()`` pair — the
    experiment layer prepares each trace once and reuses it across every
    policy family and config (and, in a trace-axis sweep, the whole grid).
    ``devices`` shards each bucket's app rows (see
    :mod:`repro.distributed.scaleout`; results stay bit-identical).
    """
    from ..distributed import scaleout
    times, counts = padded if padded is not None else trace.to_padded()
    S, n = len(keeps), trace.n_apps
    mesh = scaleout.mesh_for(devices)
    cold = np.zeros((S, n), np.int64)
    waste = np.zeros((S, n), np.float64)
    with enable_x64():
        ks = jnp.asarray(np.asarray(keeps, np.float64)[:, None])
        dur = jnp.float64(trace.duration_minutes)
        for sel, sub in _buckets(times, counts):
            sub = np.ascontiguousarray(sub, np.float64)
            if mesh is None:
                c, w = _fixed_scan(jnp.asarray(sub), ks, dur,
                                   include_trailing)
            else:
                sub = scaleout.pad_app_rows(sub, mesh.devices.size)
                dev = jax.device_put(sub, scaleout.app_sharding(mesh, 2))
                c, w = _fixed_scan_sharded(dev, ks, dur, include_trailing,
                                           mesh)
            cold[:, sel] = np.asarray(c)[:, :len(sel)]
            waste[:, sel] = np.asarray(w)[:, :len(sel)]
    keep = np.broadcast_to(np.asarray(keeps, np.float64)[:, None],
                           (S, n)).copy()
    return dict(cold=cold, invocations=counts.astype(np.int64),
                wasted_minutes=waste, final_prewarm=np.zeros((S, n)),
                final_keep_alive=keep)


# --------------------------------------------------------------------------
# Vectorized JAX engines — SPES predictor family
# --------------------------------------------------------------------------


def _spes_knobs(cfgs) -> policy_math.SpesStepConfig:
    """Stack S predictor configs into traced [S, 1] knob columns.

    Each config goes through ``SpesStepConfig.from_host`` first, so host
    rounding (e.g. ``1 - alpha``) happens exactly once and the traced knobs
    equal the scalar policy's by construction.
    """
    ks = [policy_math.SpesStepConfig.from_host(
        alpha=c.alpha, band_margin=c.band_margin, band_sigma=c.band_sigma,
        min_samples=c.min_samples, standard_keep=c.standard_keep_alive)
        for c in cfgs]
    col = lambda xs, dt: jnp.asarray(np.asarray(xs, dt)[:, None])
    return policy_math.SpesStepConfig(
        alpha=col([k.alpha for k in ks], np.float32),
        om_alpha=col([k.om_alpha for k in ks], np.float32),
        band_margin=col([k.band_margin for k in ks], np.float32),
        band_sigma=col([k.band_sigma for k in ks], np.float32),
        min_samples=col([k.min_samples for k in ks], np.int32),
        standard_keep=col([k.standard_keep for k in ks], np.float32))


@jax.jit
def _spes_scan(times, knobs: policy_math.SpesStepConfig):
    """Scan one event-count bucket for S stacked predictor configs.

    times: [n, width]; knob leaves: [S, 1] (traced — a new grid point never
    retraces). The forecast state is float32 regardless of the time dtype
    (see ``policy_math.spes_update``); the clock and observation count are
    config-independent. Trailing waste is left to the host
    (``_absolute_results``), so the float32 rebased path shares this
    program. Returns (cold [S,n], waste [S,n], last_t [n], load [S,n],
    unload [S,n]).
    """
    n = times.shape[0]
    S = knobs.alpha.shape[0]
    tdtype = times.dtype
    init = (
        jnp.full((n,), -jnp.inf, tdtype),                  # shared clock
        jnp.zeros((S, n), jnp.float32),                    # EW mean
        jnp.zeros((S, n), jnp.float32),                    # EW residual var
        jnp.zeros((n,), jnp.int32),                        # observations
        jnp.zeros((S, n), tdtype),                         # load bound
        jnp.broadcast_to(knobs.standard_keep.astype(tdtype), (S, n)),
        jnp.zeros((S, n), jnp.int32),                      # cold
        jnp.zeros((S, n), tdtype),                         # waste
    )
    step = lambda carry, t: (
        policy_math.fused_spes_step_math(t, *carry, cfg=knobs), None)
    carry, _ = jax.lax.scan(step, init, times.T)
    (last_t, _, _, _, load, unload, cold, waste) = carry
    return cold, waste, last_t, load, unload


@partial(jax.jit, static_argnums=(2,))
def _spes_scan_sharded(times, knobs: policy_math.SpesStepConfig, mesh):
    """:func:`_spes_scan` partitioned along the app axis of ``mesh``.

    The knob columns replicate; every output carries apps on its last
    axis, so shard outputs concatenate in fixed device order —
    bit-identical to the unsharded scan (no cross-app math in the step).
    """
    from ..distributed.scaleout import shard_along_apps
    fn = lambda ts, ks: _spes_scan(ts, ks)
    return shard_along_apps(fn, mesh, (0, None), -1)(times, knobs)


def _run_spes_sweep(trace: Trace, cfgs, include_trailing: bool = True, *,
                    app_chunk: Optional[int] = None,
                    padded=None, devices=None) -> dict:
    """S SPES predictor configs over one bucketed/chunked trace pass.

    Always the float64 fused path (under x64): like the fixed family, this
    family has no per-bin state, and the float32 decision layer
    (``policy_math.spes_update`` rounds once from a float64 computation)
    makes the scan oracle-exact — so the "pallas"/"reference" engines
    alias it. ``devices`` shards each chunk's app rows like the other
    sweep engines.
    """
    from ..distributed import scaleout
    times, counts = padded if padded is not None else trace.to_padded()
    S, n = len(cfgs), trace.n_apps
    mesh = scaleout.mesh_for(devices)
    ndev = 1 if mesh is None else mesh.devices.size
    knobs = _spes_knobs(cfgs)
    cold = np.zeros((S, n), np.int64)
    waste = np.zeros((S, n), np.float64)
    pre = np.zeros((S, n), np.float64)
    keep = np.empty((S, n), np.float64)
    for s, c in enumerate(cfgs):
        keep[s, :] = c.standard_keep_alive   # zero-event rows: never scanned
    duration = float(trace.duration_minutes)
    if app_chunk is None:
        chunk = max(DEFAULT_APP_CHUNK // max(S, 1), _MIN_AUTO_CHUNK)
    else:
        chunk = int(app_chunk)
    with enable_x64():
        for sel, sub in _chunked_buckets(times, counts, chunk):
            sub = np.ascontiguousarray(sub, np.float64)
            if mesh is None:
                c, w, last_t, lo, ub = _spes_scan(jax.device_put(sub), knobs)
            else:
                sub = scaleout.pad_app_rows(sub, ndev)
                dev = jax.device_put(sub, scaleout.app_sharding(mesh, 2))
                c, w, last_t, lo, ub = _spes_scan_sharded(dev, knobs, mesh)
            k = len(sel)
            c, w, lo, ub = (np.asarray(x)[..., :k] for x in (c, w, lo, ub))
            last_t = np.asarray(last_t)[:k]
            t0 = np.zeros(k, np.float64)
            cold[:, sel] = c
            waste[:, sel], pre[:, sel], keep[:, sel] = _absolute_results(
                w, last_t, lo, ub, t0, duration, include_trailing)
    return dict(cold=cold, invocations=counts.astype(np.int64),
                wasted_minutes=waste, final_prewarm=pre,
                final_keep_alive=keep)


def _buckets(times: np.ndarray, counts: np.ndarray):
    """Yield (app_index_array, trimmed_times) grouped by event count."""
    lo = 0
    for edge in BUCKET_EDGES:
        sel = np.where((counts > lo) & (counts <= edge))[0]
        if len(sel):
            width = int(counts[sel].max())
            yield sel, times[sel][:, :width]
        lo = edge


def _chunked_buckets(times: np.ndarray, counts: np.ndarray, app_chunk: int):
    """Bucket by event count, then chunk each bucket over apps.

    The last chunk of a bucket is ragged when the bucket size is not a
    multiple of ``app_chunk`` — every consumer below handles that, but an
    invalid chunk size is rejected loudly here rather than producing empty
    chunks downstream.
    """
    if app_chunk < 1:
        raise ValueError(
            f"app_chunk must be a positive app count, got {app_chunk}")
    for sel, sub in _buckets(times, counts):
        _check_scan_width(sub.shape[1])
        for lo in range(0, len(sel), app_chunk):
            yield sel[lo:lo + app_chunk], sub[lo:lo + app_chunk]


def _check_scan_width(width: int) -> None:
    """The scaled percentile compare (policy_math) multiplies cumulative
    counts — bounded by the scan width — by PCT_SCALE in int32; guard every
    engine identically rather than overflowing silently."""
    if width > policy_math.MAX_SCALED_COUNT:
        raise ValueError(
            f"bucket scan width {width} overflows the int32 scaled "
            f"percentile compare (max {policy_math.MAX_SCALED_COUNT} "
            f"events per app)")


# --------------------------------------------------------------------------
# Vectorized JAX engines — hybrid histogram family (the sweep engine)
# --------------------------------------------------------------------------


def _cum_dtype_for(width: int):
    """Narrowest cum-count dtype for a bucket scanning ``width`` events.

    Per-app cumulative counts are bounded by the bucket's scan length, so
    short-trace buckets (the overwhelming majority of a realistic fleet) can
    carry int8/int16 state — the suffix add over [n_apps, n_bins] is the
    bandwidth hot spot of the whole simulation.
    """
    if width <= 127:
        return jnp.int8
    if width <= 32766:
        return jnp.int16
    return jnp.int32


def _step_config_for(cfg: HybridConfig) -> policy_math.HybridStepConfig:
    h = cfg.histogram
    return policy_math.HybridStepConfig.from_host(
        n_bins=h.n_bins, head_pct=h.head_percentile,
        tail_pct=h.tail_percentile, margin=h.margin,
        bin_minutes=h.bin_minutes, range_minutes=h.range_minutes,
        cv_threshold=cfg.cv_threshold, min_samples=cfg.min_samples,
        oob_threshold=cfg.oob_fraction_threshold,
        standard_keep=cfg.standard_keep_alive)


def _build_sweep_block(cfgs: Sequence[HybridConfig],
                       time_dtype) -> policy_math.HybridSweepBlock:
    """Factor S hybrid configs into the group/window/gate/config layers.

    All configs must share ``n_bins`` (the driver bands by it); within a
    band the distinct (bin_minutes, n_bins) pairs become histogram groups,
    distinct window/gate knob tuples become variants, and each config keeps
    only selector indices — see ``policy_math.HybridSweepBlock``.
    """
    base = [_step_config_for(c) for c in cfgs]
    groups, g_of = {}, []
    for c in base:
        key = (float(c.bin_minutes), int(c.n_bins))
        g_of.append(groups.setdefault(key, len(groups)))
    wvars, w_of = {}, []
    for gi, c in zip(g_of, base):
        key = (gi, int(c.head_numer), int(c.tail_numer), float(c.bin_f32),
               float(c.range_f32), float(c.margin_lo), float(c.margin_hi))
        w_of.append(wvars.setdefault(key, len(wvars)))
    tvars, t_of = {}, []
    for gi, c in zip(g_of, base):
        key = (gi, int(c.min_samples), float(c.cv_threshold),
               float(c.oob_threshold))
        t_of.append(tvars.setdefault(key, len(tvars)))
    dvars, d_of = {}, []
    for c in base:
        d_of.append(dvars.setdefault(float(c.standard_keep), len(dvars)))
    col = lambda vals, dt: np.asarray(vals, dt)[:, None]
    gk, wk, tk = list(groups), list(wvars), list(tvars)
    return policy_math.HybridSweepBlock(
        g_bin_minutes=col([k[0] for k in gk], time_dtype),
        g_n_bins=col([k[1] for k in gk], np.int32),
        w_group=np.asarray([k[0] for k in wk], np.int32),
        w_head_numer=col([k[1] for k in wk], np.int32),
        w_tail_numer=col([k[2] for k in wk], np.int32),
        w_bin_f32=col([k[3] for k in wk], np.float32),
        w_range_f32=col([k[4] for k in wk], np.float32),
        w_margin_lo=col([k[5] for k in wk], np.float32),
        w_margin_hi=col([k[6] for k in wk], np.float32),
        t_group=np.asarray([k[0] for k in tk], np.int32),
        t_min_samples=col([k[1] for k in tk], np.int32),
        t_cv_threshold=col([k[2] for k in tk], np.float32),
        t_oob_threshold=col([k[3] for k in tk], np.float32),
        d_standard_keep=col(list(dvars), np.float32),
        c_window=np.asarray(w_of, np.int32),
        c_gate=np.asarray(t_of, np.int32),
        c_std=np.asarray(d_of, np.int32),
    )


def _build_pallas_cfg(cfgs: Sequence[HybridConfig]):
    """Pack S configs into the (int32, float32) SMEM config blocks the
    Pallas sweep kernel reads via scalar prefetch."""
    rows_i, rows_f = [], []
    for c in cfgs:
        h = _step_config_for(c)
        rows_i.append([h.n_bins, h.head_numer, h.tail_numer, h.min_samples])
        rows_f.append([h.margin_lo, h.margin_hi, h.bin_f32, h.range_f32,
                       h.cv_threshold, h.oob_threshold, h.standard_keep])
    return np.asarray(rows_i, np.int32), np.asarray(rows_f, np.float32)


def _sweep_identities(
        blk: policy_math.HybridSweepBlock) -> policy_math.SweepIdentities:
    """Static structure of a sweep block: which selector arrays are the
    identity (all of them, for a single-config run), so the traced layers
    skip those gathers — see ``policy_math.SweepIdentities``."""
    ident = lambda idx, m: (idx.shape[0] == m
                            and np.array_equal(np.asarray(idx), np.arange(m)))
    G = blk.g_n_bins.shape[0]
    W = blk.w_group.shape[0]
    T = blk.t_group.shape[0]
    D = blk.d_standard_keep.shape[0]
    return policy_math.SweepIdentities(
        w=ident(blk.w_group, G), t=ident(blk.t_group, G),
        c_window=ident(blk.c_window, W), c_gate=ident(blk.c_gate, T),
        c_std=ident(blk.c_std, D))


@partial(jax.jit, static_argnums=(2, 3, 4))
def _hybrid_sweep_scan(times, blk: policy_math.HybridSweepBlock,
                       cum_dtype, n_bins: int,
                       ids: policy_math.SweepIdentities =
                       policy_math.SweepIdentities()):
    """One factored sweep scan over a [n, width] chunk; S configs in one
    pass, config knobs traced (a new grid point never recompiles). The
    residency bounds are carried through the scan (refreshed at each app's
    events from the post-update group state — see
    ``policy_math.fused_hybrid_sweep_step_math``), so the final bounds ARE
    the windows decided at each app's last event; the init carry is
    decide(zero state) = (0, standard_keep)."""
    n = times.shape[0]
    tdtype = times.dtype
    _check_scan_width(times.shape[1])
    if blk.g_n_bins.ndim == 0:
        # Degenerate single-config block (scalar knob leaves): rank-2/1
        # state, no config axis anywhere — the layers broadcast against
        # scalars, reproducing the dedicated pre-sweep engine's program
        # (leading unit axes measurably pessimize XLA:CPU).
        layer = lambda *a: ()
    else:
        layer = lambda leaf: (leaf.shape[0],)
    gd = layer(blk.g_n_bins)
    sd = layer(blk.c_window)
    std = blk.d_standard_keep if ids.c_std else blk.d_standard_keep[blk.c_std]
    init = (
        jnp.full((n,), -jnp.inf, tdtype),                  # shared clock
        jnp.zeros(gd + (n, n_bins), cum_dtype),
        jnp.zeros(gd + (n,), jnp.int32),
        jnp.zeros(gd + (n,), tdtype),                      # cv_sum
        jnp.zeros(gd + (n,), tdtype),                      # cv_sum_sq
        jnp.zeros(sd + (n,), tdtype),                      # load bound
        jnp.broadcast_to(std.astype(tdtype), sd + (n,)),   # unload bound
        jnp.zeros(sd + (n,), jnp.int32),                   # cold
        jnp.zeros(sd + (n,), tdtype),                      # waste
    )
    step = lambda carry, t: (
        policy_math.fused_hybrid_sweep_step_math(
            t, *carry, blk=blk, ids=ids), None)
    carry, _ = jax.lax.scan(step, init, times.T)
    (last_t, gcum, goob, gcv_sum, gcv_sum_sq, prewarm, unload_at,
     cold, waste) = carry
    gtotal = gcum[..., -1].astype(jnp.int32)
    sel_t = (lambda x: x) if ids.t else (lambda x: x[blk.t_group])
    oobh = policy_math.oob_heavy(sel_t(gtotal), sel_t(goob),
                                 blk.t_oob_threshold)
    if not ids.c_gate:
        oobh = oobh[blk.c_gate]
    return cold, waste, oobh, last_t, prewarm, unload_at


@partial(jax.jit, static_argnums=(3, 4, 5))
def _hybrid_sweep_scan_pallas(times, cfg_i32, cfg_f32, n_bins: int,
                              interpret: bool = True, tile_apps: int = 512):
    """Same sweep, stepping through the Pallas TPU kernel (float32; the
    driver feeds per-chunk *rebased* times — see module docstring). The
    config block rides in SMEM via scalar prefetch; per-config state is
    carried unfactored (grid (S, app tiles))."""
    from ..kernels.histogram import fused_hybrid_sweep_step_pallas

    S = cfg_i32.shape[0]
    # Pad the app dimension to the kernel tile ONCE, outside the scan —
    # otherwise the kernel wrapper re-pads and re-slices the whole carry
    # (including [S, n, n_bins] cum) on every scan step. Padded rows carry
    # t = +inf and are never active.
    n_real = times.shape[0]
    pad = (-n_real) % min(tile_apps, n_real) if n_real else 0
    if pad:
        times = jnp.concatenate(
            [times, jnp.full((pad, times.shape[1]), jnp.inf, times.dtype)])
    n = times.shape[0]
    init = (
        jnp.full((S, n), -jnp.inf, jnp.float32),
        jnp.zeros((S, n, n_bins), jnp.int32),
        jnp.zeros((S, n), jnp.int32),
        jnp.zeros((S, n), jnp.float32),
        jnp.zeros((S, n), jnp.float32),
        jnp.zeros((S, n), jnp.float32),                    # prewarm
        jnp.broadcast_to(cfg_f32[:, 6:7], (S, n)),         # unload_at
        jnp.zeros((S, n), jnp.int32),
        jnp.zeros((S, n), jnp.float32),
    )

    def step(carry, t_now):
        out = fused_hybrid_sweep_step_pallas(
            t_now, *carry, cfg_i32, cfg_f32, tile_apps=tile_apps,
            interpret=interpret)
        return out, None

    carry, _ = jax.lax.scan(step, init, times.T)
    carry = tuple(c[..., :n_real, :] if c.ndim == 3 else c[..., :n_real]
                  for c in carry)
    (prev_t, cum, oob, _, _, prewarm, unload_at, cold, waste) = carry
    total = cum[..., -1]
    oob_heavy = policy_math.oob_heavy(total, oob, cfg_f32[:, 5:6])
    # the clock is config-independent: any row of prev_t is the last event
    return cold, waste, oob_heavy, prev_t[0], prewarm, unload_at


@partial(jax.jit, static_argnums=(2, 3, 4, 5))
def _hybrid_sweep_scan_sharded(times, blk: policy_math.HybridSweepBlock,
                               cum_dtype, n_bins: int,
                               ids: policy_math.SweepIdentities, mesh):
    """:func:`_hybrid_sweep_scan` partitioned along the app axis of
    ``mesh``.

    The config block replicates; every output of the factored scan carries
    apps on its LAST axis, so out_axes=-1 reassembles shards in fixed
    device order — bit-identical to the unsharded scan (no collectives, no
    cross-app math anywhere in the step). Callers pad rows to a multiple
    of the mesh size (+inf rows are masked by the scan's own ``isfinite``
    gate) and slice the outputs back.
    """
    from ..distributed.scaleout import shard_along_apps
    fn = lambda ts, b: _hybrid_sweep_scan(ts, b, cum_dtype, n_bins, ids)
    return shard_along_apps(fn, mesh, (0, None), -1)(times, blk)


@partial(jax.jit, static_argnums=(3, 4, 5, 6))
def _hybrid_sweep_scan_pallas_sharded(times, cfg_i32, cfg_f32, n_bins: int,
                                      interpret: bool, tile_apps: int, mesh):
    """:func:`_hybrid_sweep_scan_pallas` partitioned along the app axis.

    Each shard pads its own rows to the kernel tile and slices them back,
    so the assembled outputs keep the driver's row count; the SMEM config
    blocks replicate."""
    from ..distributed.scaleout import shard_along_apps
    fn = lambda ts, ci, cf: _hybrid_sweep_scan_pallas(
        ts, ci, cf, n_bins, interpret, tile_apps)
    return shard_along_apps(fn, mesh, (0, None, None), -1)(
        times, cfg_i32, cfg_f32)


def _rebase_chunk(sub: np.ndarray):
    """Per-chunk time rebasing for the float32 engines.

    Shifts each app's timestamps by its own first event (the chunk's
    row-wise minimum — times are sorted), in float64 on the host, BEFORE the
    cast to float32. Policy verdicts depend only on inter-arrival times, so
    the shift changes nothing semantically while keeping multi-week clocks
    small enough for float32 to hold the fine IAT structure. Padding (+inf)
    is unaffected. Returns (rebased float64 array, per-app offsets).
    """
    t0 = sub[:, 0].astype(np.float64)
    return sub.astype(np.float64) - t0[:, None], t0


def _absolute_results(waste, last_t, prewarm, unload_at, t0, duration,
                      include_trailing):
    """Reconstruct absolute-time results after a (possibly rebased) scan.

    Trailing waste is computed on the host in float64 from the un-rebased
    last-event clock, so the float32 engines never difference the large
    absolute timestamps. Works for [n] rows and stacked [S, n] sweeps
    (``last_t``/``t0`` broadcast along the config axis). Returns
    (waste64, prewarm64, keep64).
    """
    pre = np.asarray(prewarm, np.float64)
    ub = np.asarray(unload_at, np.float64)
    waste = np.asarray(waste, np.float64)
    if include_trailing:
        tail_gap = duration - (t0 + np.asarray(last_t, np.float64))
        waste = waste + policy_math.idle_from_bounds(tail_gap, pre, ub)
    return waste, pre, ub - pre


def _run_hybrid_sweep(trace: Trace, hybrids: Sequence[HybridConfig],
                      include_trailing: bool = True, *,
                      app_chunk: Optional[int] = None,
                      use_pallas: Optional[bool] = None,
                      interpret: Optional[bool] = None,
                      tile_apps: int = 512,
                      padded=None, devices=None) -> dict:
    """S hybrid configs over one bucketed/chunked/rebased trace pass.

    Configs are banded by bin count (so no config pays for another's wider
    histogram), but the trace preparation (``padded`` arrives precomputed
    from the experiment layer), each chunk's host→device transfer, and —
    within a band — the whole time layer and per-group histogram update are
    shared across the grid. ``use_pallas`` defaults to True on TPU (float32
    sweep kernel, per-chunk time rebasing) and False elsewhere (float64 jnp
    sweep, always oracle-exact). The scalar ARIMA post-pass runs per config
    on its own OOB-heavy apps.

    ``devices`` (None | int | "auto", see ``scaleout.mesh_for``) shards
    each chunk's app rows across a 1-D mesh: chunks are padded to a
    multiple of the mesh with masked +inf rows, ``device_put`` with a
    row sharding turns the one-chunk lookahead into per-device double
    buffering, and shard outputs concatenate in fixed device order —
    results stay bit-identical to the single-device run.
    """
    from ..distributed import scaleout
    S = len(hybrids)
    mesh = scaleout.mesh_for(devices)
    ndev = 1 if mesh is None else mesh.devices.size
    times, counts = padded if padded is not None else trace.to_padded()
    n = trace.n_apps
    cold = np.zeros((S, n), np.int64)
    waste = np.zeros((S, n), np.float64)
    pre = np.zeros((S, n), np.float64)
    keep = np.empty((S, n), np.float64)
    for s, h in enumerate(hybrids):
        keep[s, :] = h.standard_keep_alive
    oob_flags = np.zeros((S, n), bool)
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if interpret is None:
        from ..kernels import ops
        interpret = ops.INTERPRET
    duration = float(trace.duration_minutes)

    # Band configs by bin count; one scan per band, shared trace prep.
    band_of = {}
    for s, h in enumerate(hybrids):
        band_of.setdefault(h.histogram.n_bins, []).append(s)
    if app_chunk is None:
        # Bands run sequentially per chunk, so peak state scales with the
        # LARGEST band, not the whole grid. The Pallas path carries
        # per-config [S_band, chunk, n_bins] histogram state; the factored
        # jnp path carries it per GROUP, so its chunks can stay near the
        # single-config size (bigger chunks amortize per-op overhead
        # measurably on CPU).
        widest = max(len(idx) for idx in band_of.values())
        denom = widest if use_pallas else max(-(-widest // 16), 1)
        chunk = max(DEFAULT_APP_CHUNK // denom, _MIN_AUTO_CHUNK)
    else:
        chunk = int(app_chunk)
    bands = []
    for n_bins, idx in sorted(band_of.items()):
        cfgs = [hybrids[s] for s in idx]
        if use_pallas:
            ci, cf = _build_pallas_cfg(cfgs)
            if mesh is None:
                fn = partial(_hybrid_sweep_scan_pallas, cfg_i32=ci,
                             cfg_f32=cf, n_bins=n_bins, interpret=interpret,
                             tile_apps=tile_apps)
            else:
                fn = lambda cur, ci=ci, cf=cf, nb=n_bins: \
                    _hybrid_sweep_scan_pallas_sharded(
                        cur, ci, cf, nb, interpret, tile_apps, mesh)
        else:
            blk = _build_sweep_block(cfgs, np.float64)
            ids = _sweep_identities(blk)
            if len(cfgs) == 1:
                # scalar knob leaves -> the scan drops the config axis
                # entirely (see _hybrid_sweep_scan)
                blk = policy_math.HybridSweepBlock(
                    *(np.asarray(x).reshape(()) for x in blk))
            if mesh is None:
                fn = lambda cur, blk=blk, nb=n_bins, ids=ids: \
                    _hybrid_sweep_scan(
                        cur, blk, _cum_dtype_for(cur.shape[1]), nb, ids)
            else:
                fn = lambda cur, blk=blk, nb=n_bins, ids=ids: \
                    _hybrid_sweep_scan_sharded(
                        cur, blk, _cum_dtype_for(cur.shape[1]), nb, ids,
                        mesh)
        bands.append((np.asarray(idx), fn))

    run_dtype = np.float32 if use_pallas else np.float64

    def run_all():
        # Streaming with a one-chunk lookahead: at most two chunk copies are
        # alive at once (the one scanning and the one whose host->device
        # transfer is enqueued ahead of blocking on the current result).
        # With a mesh, the row-sharded device_put enqueues one transfer PER
        # DEVICE, so the lookahead double-buffers per device.
        def prep(sel_sub):
            sel, sub = sel_sub
            if use_pallas:
                sub, t0 = _rebase_chunk(sub)
            else:
                t0 = np.zeros(len(sel), np.float64)
            sub = np.ascontiguousarray(sub, run_dtype)
            if mesh is None:
                return sel, jax.device_put(sub), t0
            sub = scaleout.pad_app_rows(sub, ndev)
            return sel, jax.device_put(
                sub, scaleout.app_sharding(mesh, sub.ndim)), t0

        work = _chunked_buckets(times, counts, chunk)
        pending = next(work, None)
        if pending is None:
            return
        pending = prep(pending)
        while pending is not None:
            sel, cur, t0 = pending
            nxt = next(work, None)
            pending = None if nxt is None else prep(nxt)
            for idx, fn in bands:
                # [..., :len(sel)] drops the masked mesh-padding rows (a
                # no-op on the unsharded path).
                c, w, oobh, last_t, pw, ub = (
                    np.asarray(o)[..., :len(sel)] for o in fn(cur))
                at = np.ix_(idx, sel)
                cold[at] = c
                oob_flags[at] = oobh
                waste[at], pre[at], keep[at] = _absolute_results(
                    w, last_t, pw, ub, t0, duration, include_trailing)

    if use_pallas:
        run_all()
    else:
        with enable_x64():
            run_all()

    # Forecast post-pass: a forecaster cannot run inside the scan, so each
    # config's OOB-heavy apps replay through the batched forecasting
    # subsystem (one fused-step rescan + one grid ARIMA fit over every
    # flagged (app, event) window — bit-identical to the scalar policy,
    # see repro.forecast.replay).
    for s, h in enumerate(hybrids):
        if h.use_arima and oob_flags[s].any():
            from ..forecast.replay import replay_oob_apps
            aidx = np.where(oob_flags[s])[0]
            out = replay_oob_apps(times, counts, duration, h, aidx,
                                  include_trailing)
            cold[s, aidx] = out["cold"]
            waste[s, aidx] = out["wasted_minutes"]
            pre[s, aidx] = out["final_prewarm"]
            keep[s, aidx] = out["final_keep_alive"]
    return dict(cold=cold, invocations=counts.astype(np.int64),
                wasted_minutes=waste, final_prewarm=pre,
                final_keep_alive=keep)


# -- pre-sweep batched engine (benchmark/regression baseline) ----------------


def _hybrid_step_reference(cfg: HistogramConfig, hybrid: HybridConfig, carry,
                           t_now):
    """Legacy fused step: raw counts + a full [n_apps, n_bins] cumsum and
    percentile search per scan step — the step-throughput baseline the
    incremental cumulative-count engine is benchmarked against. Decision
    math is the same single-source helpers as every other engine."""
    (prev_t, counts, total, oob, cv_sum, cv_sum_sq, prewarm, unload_at,
     cold, waste) = carry
    valid = jnp.isfinite(t_now)
    first = ~jnp.isfinite(prev_t)
    it = t_now - prev_t

    warm = policy_math.warm_from_bounds(it, prewarm, unload_at)
    is_cold = valid & (first | ~warm)
    gap_waste = jnp.where(valid & ~first,
                          policy_math.idle_from_bounds(it, prewarm, unload_at),
                          0.0)

    rec = valid & ~first
    safe, in_b, oob_hit = policy_math.classify_idle_time(
        it, rec, cfg.bin_minutes, cfg.n_bins)
    rows = jnp.arange(counts.shape[0])
    old = counts[rows, safe]
    counts = counts.at[rows, safe].add(in_b.astype(jnp.int32))
    total = total + in_b.astype(jnp.int32)
    oob = oob + oob_hit.astype(jnp.int32)
    cv_sum, cv_sum_sq = policy_math.welford_update(cv_sum, cv_sum_sq, in_b,
                                                   old)

    cum = jnp.cumsum(counts, axis=-1)   # the per-step recompute (baseline)
    # masked-reduction search: the same one-sweep structure as the legacy
    # argmax (the binary-search form would distort the baseline's cost)
    head_bin = policy_math.first_bin_ge_scaled(
        cum, policy_math.percentile_threshold_scaled(
            total, cfg.head_percentile), gather=False)
    tail_bin = policy_math.first_bin_ge_scaled(
        cum, policy_math.percentile_threshold_scaled(
            total, cfg.tail_percentile), gather=False) + 1
    new_load, new_unload = policy_math.window_values(
        head_bin, tail_bin, cfg.bin_minutes, cfg.range_minutes, cfg.margin)
    use_hist = policy_math.use_histogram_gate(
        total, oob, cv_sum, cv_sum_sq, cfg.n_bins, hybrid.min_samples,
        hybrid.cv_threshold, hybrid.oob_fraction_threshold)
    std_load, std_unload = policy_math.standard_window_bounds(
        hybrid.standard_keep_alive)
    new_load = jnp.where(use_hist, new_load, std_load)
    new_unload = jnp.where(use_hist, new_unload, std_unload)

    prewarm = jnp.where(valid, new_load, prewarm)
    unload_at = jnp.where(valid, new_unload, unload_at)
    prev_t = jnp.where(valid, t_now, prev_t)
    return (prev_t, counts, total, oob, cv_sum, cv_sum_sq, prewarm, unload_at,
            cold + is_cold, waste + gap_waste), None


@partial(jax.jit, static_argnums=(1, 2))
def _hybrid_scan_reference(times, cfg: HistogramConfig, hybrid: HybridConfig):
    n = times.shape[0]
    n_bins = cfg.n_bins
    init = (
        jnp.full((n,), -jnp.inf, jnp.float32),
        jnp.zeros((n, n_bins), jnp.int32),
        jnp.zeros((n,), jnp.int32),
        jnp.zeros((n,), jnp.int32),
        jnp.zeros((n,), jnp.float32),
        jnp.zeros((n,), jnp.float32),
        jnp.zeros((n,), jnp.float32),                                 # prewarm
        jnp.full((n,), jnp.float32(hybrid.standard_keep_alive)),      # unload_at
        jnp.zeros((n,), jnp.int32),
        jnp.zeros((n,), jnp.float32),
    )
    carry, _ = jax.lax.scan(partial(_hybrid_step_reference, cfg, hybrid),
                            init, times.T)
    (last_t, counts, total, oob, _, _, prewarm, unload_at, cold, waste) = carry
    oob_heavy = policy_math.oob_heavy(total, oob,
                                      hybrid.oob_fraction_threshold)
    return cold, waste, oob_heavy, last_t, prewarm, unload_at


def _simulate_hybrid_batch_reference(trace: Trace, hybrid: HybridConfig,
                                     include_trailing: bool = True,
                                     padded=None) -> SimResult:
    """Pre-sweep batched hybrid engine (float32, per-step cumsum recompute,
    per-chunk time rebasing like the Pallas path)."""
    times, counts = padded if padded is not None else trace.to_padded()
    n = trace.n_apps
    cold_parts = np.zeros(n, np.int64)
    waste_parts = np.zeros(n, np.float64)
    pre_parts = np.zeros(n, np.float64)
    keep_parts = np.full(n, hybrid.standard_keep_alive, np.float64)
    oob_flags = np.zeros(n, bool)
    duration = float(trace.duration_minutes)
    for sel, sub in _buckets(times, counts):
        _check_scan_width(sub.shape[1])
        sub, t0 = _rebase_chunk(sub)
        cold, waste, oobh, last_t, prewarm, unload_at = \
            _hybrid_scan_reference(jnp.asarray(sub, jnp.float32),
                                   hybrid.histogram, hybrid)
        cold_parts[sel] = np.asarray(cold)
        oob_flags[sel] = np.asarray(oobh)
        waste_parts[sel], pre_parts[sel], keep_parts[sel] = \
            _absolute_results(waste, last_t, prewarm, unload_at, t0,
                              duration, include_trailing)
    result = SimResult(cold_parts, counts.astype(np.int64), waste_parts,
                       pre_parts, keep_parts)
    if hybrid.use_arima and oob_flags.any():
        from ..forecast.replay import replay_oob_apps
        arima_idx = np.where(oob_flags)[0]
        out = replay_oob_apps(times, counts, duration, hybrid, arima_idx,
                              include_trailing)
        result.cold[arima_idx] = out["cold"]
        result.wasted_minutes[arima_idx] = out["wasted_minutes"]
        result.final_prewarm[arima_idx] = out["final_prewarm"]
        result.final_keep_alive[arima_idx] = out["final_keep_alive"]
    return result


# --------------------------------------------------------------------------
# Removed entry points (deprecation cycle completed in PR 3 -> PR 5)
# --------------------------------------------------------------------------

_REMOVED = {
    "simulate": "run(trace, spec)",
    "simulate_fixed_batch": "run(trace, FixedSpec(keep_alive))",
    "simulate_hybrid_batch": "run(trace, HybridSpec(...))",
    "simulate_hybrid_batch_reference": 'run(trace, spec, engine="reference")',
}


def __getattr__(name: str):
    if name in _REMOVED:
        raise AttributeError(
            f"repro.core.simulator.{name} was removed after its deprecation "
            f"cycle; use repro.core.experiment.{_REMOVED[name]} instead "
            f"(arbitrary Policy objects still run via simulate_scalar)")
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
