"""Trace-driven cold-start simulator (Section 5.1 of the paper).

Four interchangeable engines, all computing their decisions through the
single-source policy math in :mod:`repro.core.policy_math`:

  * :func:`simulate_scalar` — event-driven reference. Walks each app's
    invocation sequence, querying any :class:`repro.core.policy.Policy`
    (including the full hybrid policy with its ARIMA path). This is the
    float64 oracle and handles arbitrary policies.

  * :func:`simulate_hybrid_batch` / :func:`simulate_fixed_batch` — vectorized
    JAX engines: all apps advance together through a ``lax.scan`` over padded
    event indices. The hybrid engine carries *cumulative* per-app bin counts
    (``[n_apps, n_bins]``, narrowest integer dtype the bucket's event count
    allows) so a step's histogram update is a suffix add and the head/tail
    percentile decision is a binary search — no fleet-wide cumsum recompute
    per step. Apps are bucketed by event count so a handful of very chatty
    apps do not inflate the scan length for everyone, and each bucket is
    further chunked over apps with double-buffered host→device transfer so
    ~1M-app traces fit in device memory. ARIMA cannot run inside a scan;
    apps whose out-of-bounds fraction crosses the threshold are re-simulated
    through the scalar engine and their results overridden (the paper: these
    are ~0.7% of invocations).

  * On TPU the fused step runs as a Pallas kernel
    (:func:`repro.kernels.histogram.fused_hybrid_step_pallas`) in float32;
    pass ``use_pallas=True`` to exercise it in interpret mode elsewhere.

  * ``simulate_hybrid_batch_reference`` — the pre-fused batched engine
    (per-step full-matrix cumsum), kept as the regression baseline for the
    ``benchmarks/policy_overhead.py`` step-throughput comparison.

Float32 exactness (the TPU story): TPUs have no float64, so the Pallas and
reference engines carry float32 time state. Absolute timestamps on a
multi-week trace (t ~ 2e4 minutes) cannot hold sub-minute inter-arrival
structure in float32, so both float32 engines *rebase* each app chunk before
the scan — every app's timestamps are shifted by its own first event (the
chunk's per-row minimum), computed in float64 on the host. Policy verdicts
are invariant under time translation (a property test guards this), so the
rebased scan reproduces the float64 oracle's cold counts exactly whenever
the rebased times are float32-representable; trailing waste is reconstructed
afterward in float64 from the un-rebased clock. The decision layer itself
(percentile thresholds, windows, CV gate) is dtype-invariant by construction
— see :mod:`repro.core.policy_math`.

Exactly as in the paper, function execution time is simulated as 0 (so idle
time == inter-arrival time) to account wasted memory time conservatively, and
the first invocation of every app is a cold start.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from . import policy_math
from .histogram import HistogramConfig
from .policy import (FixedKeepAlivePolicy, HybridConfig, HybridHistogramPolicy,
                     Policy, is_warm, loaded_idle_time)
from .workload import Trace

__all__ = [
    "SimResult", "simulate_scalar", "simulate_fixed_batch",
    "simulate_hybrid_batch", "simulate_hybrid_batch_reference", "simulate",
    "BUCKET_EDGES", "DEFAULT_APP_CHUNK",
]

BUCKET_EDGES = (64, 512, 4096, 1 << 62)

# Apps per device-resident chunk of the hybrid scan: bounds the cumulative
# count state ([chunk, n_bins]) regardless of fleet size.
DEFAULT_APP_CHUNK = 131072


@dataclasses.dataclass
class SimResult:
    cold: np.ndarray            # [n_apps] cold-start counts
    invocations: np.ndarray     # [n_apps] invocation counts
    wasted_minutes: np.ndarray  # [n_apps] loaded-but-idle memory time
    # Final per-app policy windows (None for engines/paths that predate the
    # conformance harness; filled by all four engines here).
    final_prewarm: Optional[np.ndarray] = None     # [n_apps] float64
    final_keep_alive: Optional[np.ndarray] = None  # [n_apps] float64

    @property
    def cold_pct(self) -> np.ndarray:
        return 100.0 * self.cold / np.maximum(self.invocations, 1)

    def cold_pct_percentile(self, q: float = 75.0) -> float:
        return float(np.percentile(self.cold_pct, q))

    @property
    def total_wasted(self) -> float:
        return float(self.wasted_minutes.sum())

    @property
    def always_cold_fraction(self) -> float:
        # Only apps that were actually invoked can be always-cold; apps with
        # zero invocations trivially satisfy cold >= invocations (0 >= 0) and
        # must not inflate the fraction (paper Fig. 12 counts invoked apps).
        invoked = self.invocations > 0
        if not invoked.any():
            return 0.0
        return float(np.mean(self.cold[invoked] >= self.invocations[invoked]))


# --------------------------------------------------------------------------
# Scalar reference engine
# --------------------------------------------------------------------------

def simulate_scalar(trace: Trace, policy: Policy,
                    include_trailing: bool = True,
                    app_indices: Optional[Sequence[int]] = None) -> SimResult:
    idx = range(trace.n_apps) if app_indices is None else app_indices
    n = trace.n_apps
    cold = np.zeros(n, np.int64)
    inv = np.zeros(n, np.int64)
    waste = np.zeros(n, np.float64)
    final_pre = np.zeros(n, np.float64)
    final_keep = np.zeros(n, np.float64)
    for i in idx:
        t = trace.events(i)
        app = trace.app_id(i)
        inv[i] = len(t)
        w = policy.windows(app)
        if len(t):
            cold[i] += 1  # first invocation is always cold
            w = policy.on_invocation(app, None)
            for k in range(1, len(t)):
                it = float(t[k]) - float(t[k - 1])  # exec time = 0 => IT == IAT
                if not is_warm(it, w):
                    cold[i] += 1
                waste[i] += loaded_idle_time(it, w)
                w = policy.on_invocation(app, it)
            if include_trailing:
                tail_gap = trace.duration_minutes - float(t[-1])
                waste[i] += loaded_idle_time(tail_gap, w) if tail_gap > 0 else 0.0
        final_pre[i], final_keep[i] = w.prewarm, w.keep_alive
    return SimResult(cold, inv, waste, final_pre, final_keep)


# --------------------------------------------------------------------------
# Vectorized JAX engines
# --------------------------------------------------------------------------

def _fixed_step(keep_alive, carry, t_now):
    prev_t, cold, waste = carry
    valid = jnp.isfinite(t_now)
    it = t_now - prev_t
    first = ~jnp.isfinite(prev_t)
    warm = policy_math.warm_from_bounds(it, 0.0, keep_alive)
    is_cold = valid & (first | ~warm)
    gap_waste = jnp.where(valid & ~first,
                          policy_math.idle_from_bounds(it, 0.0, keep_alive),
                          0.0)
    new_prev = jnp.where(valid, t_now, prev_t)
    return (new_prev, cold + is_cold, waste + gap_waste), None


@partial(jax.jit, static_argnums=(3,))
def _fixed_scan(times, keep_alive, duration, include_trailing: bool):
    n = times.shape[0]
    tdtype = times.dtype
    init = (jnp.full((n,), -jnp.inf, tdtype),
            jnp.zeros((n,), jnp.int32), jnp.zeros((n,), tdtype))
    (last_t, cold, waste), _ = jax.lax.scan(
        partial(_fixed_step, keep_alive), init, times.T)
    if include_trailing:
        tail = jnp.maximum(duration - last_t, 0.0)
        waste = waste + jnp.where(
            jnp.isfinite(last_t),
            policy_math.idle_from_bounds(tail, 0.0, keep_alive), 0.0)
    return cold, waste


def simulate_fixed_batch(trace: Trace, keep_alive_minutes: float,
                         include_trailing: bool = True) -> SimResult:
    times, counts = trace.to_padded()
    cold_parts = np.zeros(trace.n_apps, np.int64)
    waste_parts = np.zeros(trace.n_apps, np.float64)
    # float64 time state: two-week traces (t ~ 2e4 minutes) lose the
    # sub-millisecond IAT bits in float32, flipping warm/cold verdicts
    # exactly at the keep-alive boundary vs the scalar oracle.
    with enable_x64():
        for sel, sub in _buckets(times, counts):
            cold, waste = _fixed_scan(jnp.asarray(sub, jnp.float64),
                                      jnp.float64(keep_alive_minutes),
                                      jnp.float64(trace.duration_minutes),
                                      include_trailing)
            cold_parts[sel] = np.asarray(cold)
            waste_parts[sel] = np.asarray(waste)
    n = trace.n_apps
    return SimResult(cold_parts, counts.astype(np.int64), waste_parts,
                     np.zeros(n, np.float64),
                     np.full(n, float(keep_alive_minutes), np.float64))


def _buckets(times: np.ndarray, counts: np.ndarray):
    """Yield (app_index_array, trimmed_times) grouped by event count."""
    lo = 0
    for edge in BUCKET_EDGES:
        sel = np.where((counts > lo) & (counts <= edge))[0]
        if len(sel):
            width = int(counts[sel].max())
            yield sel, times[sel][:, :width]
        lo = edge


def _chunked_buckets(times: np.ndarray, counts: np.ndarray, app_chunk: int):
    """Bucket by event count, then chunk each bucket over apps.

    The last chunk of a bucket is ragged when the bucket size is not a
    multiple of ``app_chunk`` — every consumer below handles that, but an
    invalid chunk size is rejected loudly here rather than producing empty
    chunks downstream.
    """
    if app_chunk < 1:
        raise ValueError(
            f"app_chunk must be a positive app count, got {app_chunk}")
    for sel, sub in _buckets(times, counts):
        _check_scan_width(sub.shape[1])
        for lo in range(0, len(sel), app_chunk):
            yield sel[lo:lo + app_chunk], sub[lo:lo + app_chunk]


def _check_scan_width(width: int) -> None:
    """The scaled percentile compare (policy_math) multiplies cumulative
    counts — bounded by the scan width — by PCT_SCALE in int32; guard every
    engine identically rather than overflowing silently."""
    if width * policy_math.PCT_SCALE >= 2 ** 31:
        raise ValueError(
            f"bucket scan width {width} overflows the int32 scaled "
            f"percentile compare (max {2 ** 31 // policy_math.PCT_SCALE - 1} "
            f"events per app)")


# -- hybrid ------------------------------------------------------------------


def _cum_dtype_for(width: int):
    """Narrowest cum-count dtype for a bucket scanning ``width`` events.

    Per-app cumulative counts are bounded by the bucket's scan length, so
    short-trace buckets (the overwhelming majority of a realistic fleet) can
    carry int8/int16 state — the suffix add over [n_apps, n_bins] is the
    bandwidth hot spot of the whole simulation.
    """
    if width <= 127:
        return jnp.int8
    if width <= 32766:
        return jnp.int16
    return jnp.int32


def _step_params(cfg: HistogramConfig, hybrid: HybridConfig, gather: bool):
    return dict(
        n_bins=cfg.n_bins, head_pct=cfg.head_percentile,
        tail_pct=cfg.tail_percentile, margin=cfg.margin,
        bin_minutes=cfg.bin_minutes, range_minutes=cfg.range_minutes,
        cv_threshold=hybrid.cv_threshold, min_samples=hybrid.min_samples,
        oob_threshold=hybrid.oob_fraction_threshold,
        standard_keep=hybrid.standard_keep_alive, gather=gather)


def _fused_hybrid_step(cfg: HistogramConfig, hybrid: HybridConfig, carry,
                       t_now):
    """Fused scan step — single-source math, XLA gather strategy (the Pallas
    twin is ``repro.kernels.histogram.fused_hybrid_step_pallas``)."""
    return policy_math.fused_hybrid_step_math(
        t_now, *carry, **_step_params(cfg, hybrid, gather=True)), None


@partial(jax.jit, static_argnums=(1, 2, 3))
def _hybrid_scan(times, cfg: HistogramConfig, hybrid: HybridConfig,
                 cum_dtype=jnp.int32):
    n = times.shape[0]
    tdtype = times.dtype
    _check_scan_width(times.shape[1])
    init = (
        jnp.full((n,), -jnp.inf, tdtype),
        jnp.zeros((n, cfg.n_bins), cum_dtype),
        jnp.zeros((n,), jnp.int32),
        jnp.zeros((n,), tdtype),                                      # cv_sum
        jnp.zeros((n,), tdtype),                                      # cv_sum_sq
        jnp.zeros((n,), tdtype),                                      # prewarm
        jnp.full((n,), hybrid.standard_keep_alive, tdtype),           # unload_at
        jnp.zeros((n,), jnp.int32),
        jnp.zeros((n,), tdtype),
    )
    carry, _ = jax.lax.scan(partial(_fused_hybrid_step, cfg, hybrid), init,
                            times.T)
    (last_t, cum, oob, _, _, prewarm, unload_at, cold, waste) = carry
    total = cum[:, -1].astype(jnp.int32)
    oob_heavy = policy_math.oob_heavy(total, oob,
                                      hybrid.oob_fraction_threshold)
    return cold, waste, oob_heavy, last_t, prewarm, unload_at


@partial(jax.jit, static_argnums=(1, 2, 3, 4))
def _hybrid_scan_pallas(times, cfg: HistogramConfig, hybrid: HybridConfig,
                        interpret: bool = True, tile_apps: int = 512):
    """Same fused scan, stepping through the Pallas TPU kernel (float32;
    the driver feeds per-chunk *rebased* times — see module docstring)."""
    from ..kernels.histogram import fused_hybrid_step_pallas

    # Pad the app dimension to the kernel tile ONCE, outside the scan —
    # otherwise the kernel wrapper re-pads and re-slices the whole carry
    # (including [n, n_bins] cum) on every scan step. Padded rows carry
    # t = +inf and are never active.
    n_real = times.shape[0]
    pad = (-n_real) % min(tile_apps, n_real) if n_real else 0
    if pad:
        times = jnp.concatenate(
            [times, jnp.full((pad, times.shape[1]), jnp.inf, times.dtype)])
    n = times.shape[0]
    init = (
        jnp.full((n,), -jnp.inf, jnp.float32),
        jnp.zeros((n, cfg.n_bins), jnp.int32),
        jnp.zeros((n,), jnp.int32),
        jnp.zeros((n,), jnp.float32),
        jnp.zeros((n,), jnp.float32),
        jnp.zeros((n,), jnp.float32),                                 # prewarm
        jnp.full((n,), jnp.float32(hybrid.standard_keep_alive)),      # unload_at
        jnp.zeros((n,), jnp.int32),
        jnp.zeros((n,), jnp.float32),
    )

    def step(carry, t_now):
        out = fused_hybrid_step_pallas(
            t_now, *carry,
            head_pct=cfg.head_percentile, tail_pct=cfg.tail_percentile,
            margin=cfg.margin, bin_minutes=cfg.bin_minutes,
            range_minutes=cfg.range_minutes,
            cv_threshold=hybrid.cv_threshold,
            min_samples=hybrid.min_samples,
            oob_threshold=hybrid.oob_fraction_threshold,
            standard_keep=hybrid.standard_keep_alive,
            tile_apps=tile_apps, interpret=interpret)
        return out, None

    carry, _ = jax.lax.scan(step, init, times.T)
    carry = tuple(c[:n_real] for c in carry)
    (last_t, cum, oob, _, _, prewarm, unload_at, cold, waste) = carry
    total = cum[:, -1]
    oob_heavy = policy_math.oob_heavy(total, oob,
                                      hybrid.oob_fraction_threshold)
    return cold, waste, oob_heavy, last_t, prewarm, unload_at


def _rebase_chunk(sub: np.ndarray):
    """Per-chunk time rebasing for the float32 engines.

    Shifts each app's timestamps by its own first event (the chunk's
    row-wise minimum — times are sorted), in float64 on the host, BEFORE the
    cast to float32. Policy verdicts depend only on inter-arrival times, so
    the shift changes nothing semantically while keeping multi-week clocks
    small enough for float32 to hold the fine IAT structure. Padding (+inf)
    is unaffected. Returns (rebased float64 array, per-app offsets).
    """
    t0 = sub[:, 0].astype(np.float64)
    return sub.astype(np.float64) - t0[:, None], t0


def _absolute_results(waste, last_t, prewarm, unload_at, t0, duration,
                      include_trailing):
    """Reconstruct absolute-time results after a (possibly rebased) scan.

    Trailing waste is computed on the host in float64 from the un-rebased
    last-event clock, so the float32 engines never difference the large
    absolute timestamps. Returns (waste64, prewarm64, keep64).
    """
    pre = np.asarray(prewarm, np.float64)
    ub = np.asarray(unload_at, np.float64)
    waste = np.asarray(waste, np.float64)
    if include_trailing:
        tail_gap = duration - (t0 + np.asarray(last_t, np.float64))
        waste = waste + policy_math.idle_from_bounds(tail_gap, pre, ub)
    return waste, pre, ub - pre


def simulate_hybrid_batch(trace: Trace, hybrid: HybridConfig,
                          include_trailing: bool = True, *,
                          app_chunk: Optional[int] = None,
                          use_pallas: Optional[bool] = None) -> SimResult:
    """Vectorized hybrid simulation + scalar post-pass for ARIMA apps.

    Buckets apps by event count, chunks each bucket to ``app_chunk`` apps
    (bounding device state), and streams chunks with the next host→device
    transfer overlapping the current chunk's scan. ``use_pallas`` defaults
    to True on TPU (float32 fused kernel) and False elsewhere (float64 jnp
    fused step, always oracle-exact). The Pallas path rebases each chunk by
    the per-app first event, which makes it reproduce the scalar oracle's
    cold counts exactly whenever an app's own activity *span* is
    representable on its time grid in float32 (see the module docstring) —
    true for bursty/short-lived apps however deep into a multi-week trace
    they sit, but an app spanning weeks of sub-minute-grid events still
    exceeds float32; pass ``use_pallas=False`` when oracle-exact counts
    matter more than throughput.
    """
    times, counts = trace.to_padded()
    n = trace.n_apps
    cold_parts = np.zeros(n, np.int64)
    waste_parts = np.zeros(n, np.float64)
    pre_parts = np.zeros(n, np.float64)
    keep_parts = np.full(n, hybrid.standard_keep_alive, np.float64)
    oob_flags = np.zeros(n, bool)
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    chunk = DEFAULT_APP_CHUNK if app_chunk is None else int(app_chunk)
    cfg = hybrid.histogram
    duration = float(trace.duration_minutes)

    def run_all(run_dtype, scan_fn, rebase: bool):
        # Streaming with a one-chunk lookahead: at most two chunk copies are
        # alive at once (the one scanning and the one whose host->device
        # transfer is enqueued ahead of blocking on the current result).
        def prep(sel_sub):
            sel, sub = sel_sub
            if rebase:
                sub, t0 = _rebase_chunk(sub)
            else:
                t0 = np.zeros(len(sel), np.float64)
            return sel, jax.device_put(
                np.ascontiguousarray(sub, run_dtype)), t0

        work = _chunked_buckets(times, counts, chunk)
        pending = next(work, None)
        if pending is None:
            return
        pending = prep(pending)
        while pending is not None:
            sel, cur, t0 = pending
            nxt = next(work, None)
            pending = None if nxt is None else prep(nxt)
            cold, waste, oobh, last_t, prewarm, unload_at = scan_fn(cur)
            cold_parts[sel] = np.asarray(cold)
            oob_flags[sel] = np.asarray(oobh)
            waste_parts[sel], pre_parts[sel], keep_parts[sel] = \
                _absolute_results(waste, last_t, prewarm, unload_at, t0,
                                  duration, include_trailing)

    if use_pallas:
        from ..kernels import ops
        run_all(np.float32,
                lambda cur: _hybrid_scan_pallas(cur, cfg, hybrid,
                                                ops.INTERPRET),
                rebase=True)
    else:
        with enable_x64():
            run_all(np.float64,
                    lambda cur: _hybrid_scan(cur, cfg, hybrid,
                                             _cum_dtype_for(cur.shape[1])),
                    rebase=False)
    result = SimResult(cold_parts, counts.astype(np.int64), waste_parts,
                       pre_parts, keep_parts)
    if hybrid.use_arima and oob_flags.any():
        # Re-simulate OOB-heavy apps with the full scalar policy (ARIMA path).
        policy = HybridHistogramPolicy(hybrid)
        arima_idx = np.where(oob_flags)[0]
        scalar = simulate_scalar(trace, policy, include_trailing, arima_idx)
        result.cold[arima_idx] = scalar.cold[arima_idx]
        result.wasted_minutes[arima_idx] = scalar.wasted_minutes[arima_idx]
        result.final_prewarm[arima_idx] = scalar.final_prewarm[arima_idx]
        result.final_keep_alive[arima_idx] = scalar.final_keep_alive[arima_idx]
    return result


# -- pre-PR batched engine (benchmark/regression baseline) -------------------


def _hybrid_step_reference(cfg: HistogramConfig, hybrid: HybridConfig, carry,
                           t_now):
    """Legacy fused step: raw counts + a full [n_apps, n_bins] cumsum and
    percentile search per scan step — the step-throughput baseline the
    incremental cumulative-count engine is benchmarked against. Decision
    math is the same single-source helpers as every other engine."""
    (prev_t, counts, total, oob, cv_sum, cv_sum_sq, prewarm, unload_at,
     cold, waste) = carry
    valid = jnp.isfinite(t_now)
    first = ~jnp.isfinite(prev_t)
    it = t_now - prev_t

    warm = policy_math.warm_from_bounds(it, prewarm, unload_at)
    is_cold = valid & (first | ~warm)
    gap_waste = jnp.where(valid & ~first,
                          policy_math.idle_from_bounds(it, prewarm, unload_at),
                          0.0)

    rec = valid & ~first
    safe, in_b, oob_hit = policy_math.classify_idle_time(
        it, rec, cfg.bin_minutes, cfg.n_bins)
    rows = jnp.arange(counts.shape[0])
    old = counts[rows, safe]
    counts = counts.at[rows, safe].add(in_b.astype(jnp.int32))
    total = total + in_b.astype(jnp.int32)
    oob = oob + oob_hit.astype(jnp.int32)
    cv_sum, cv_sum_sq = policy_math.welford_update(cv_sum, cv_sum_sq, in_b,
                                                   old)

    cum = jnp.cumsum(counts, axis=-1)   # the per-step recompute (baseline)
    # masked-reduction search: the same one-sweep structure as the legacy
    # argmax (the binary-search form would distort the baseline's cost)
    head_bin = policy_math.first_bin_ge_scaled(
        cum, policy_math.percentile_threshold_scaled(
            total, cfg.head_percentile), gather=False)
    tail_bin = policy_math.first_bin_ge_scaled(
        cum, policy_math.percentile_threshold_scaled(
            total, cfg.tail_percentile), gather=False) + 1
    new_load, new_unload = policy_math.window_values(
        head_bin, tail_bin, cfg.bin_minutes, cfg.range_minutes, cfg.margin)
    use_hist = policy_math.use_histogram_gate(
        total, oob, cv_sum, cv_sum_sq, cfg.n_bins, hybrid.min_samples,
        hybrid.cv_threshold, hybrid.oob_fraction_threshold)
    std_load, std_unload = policy_math.standard_window_bounds(
        hybrid.standard_keep_alive)
    new_load = jnp.where(use_hist, new_load, std_load)
    new_unload = jnp.where(use_hist, new_unload, std_unload)

    prewarm = jnp.where(valid, new_load, prewarm)
    unload_at = jnp.where(valid, new_unload, unload_at)
    prev_t = jnp.where(valid, t_now, prev_t)
    return (prev_t, counts, total, oob, cv_sum, cv_sum_sq, prewarm, unload_at,
            cold + is_cold, waste + gap_waste), None


@partial(jax.jit, static_argnums=(1, 2))
def _hybrid_scan_reference(times, cfg: HistogramConfig, hybrid: HybridConfig):
    n = times.shape[0]
    n_bins = cfg.n_bins
    init = (
        jnp.full((n,), -jnp.inf, jnp.float32),
        jnp.zeros((n, n_bins), jnp.int32),
        jnp.zeros((n,), jnp.int32),
        jnp.zeros((n,), jnp.int32),
        jnp.zeros((n,), jnp.float32),
        jnp.zeros((n,), jnp.float32),
        jnp.zeros((n,), jnp.float32),                                 # prewarm
        jnp.full((n,), jnp.float32(hybrid.standard_keep_alive)),      # unload_at
        jnp.zeros((n,), jnp.int32),
        jnp.zeros((n,), jnp.float32),
    )
    carry, _ = jax.lax.scan(partial(_hybrid_step_reference, cfg, hybrid),
                            init, times.T)
    (last_t, counts, total, oob, _, _, prewarm, unload_at, cold, waste) = carry
    oob_heavy = policy_math.oob_heavy(total, oob,
                                      hybrid.oob_fraction_threshold)
    return cold, waste, oob_heavy, last_t, prewarm, unload_at


def simulate_hybrid_batch_reference(trace: Trace, hybrid: HybridConfig,
                                    include_trailing: bool = True) -> SimResult:
    """Pre-fused batched hybrid engine (float32, per-step cumsum recompute,
    per-chunk time rebasing like the Pallas path)."""
    times, counts = trace.to_padded()
    n = trace.n_apps
    cold_parts = np.zeros(n, np.int64)
    waste_parts = np.zeros(n, np.float64)
    pre_parts = np.zeros(n, np.float64)
    keep_parts = np.full(n, hybrid.standard_keep_alive, np.float64)
    oob_flags = np.zeros(n, bool)
    duration = float(trace.duration_minutes)
    for sel, sub in _buckets(times, counts):
        _check_scan_width(sub.shape[1])
        sub, t0 = _rebase_chunk(sub)
        cold, waste, oobh, last_t, prewarm, unload_at = \
            _hybrid_scan_reference(jnp.asarray(sub, jnp.float32),
                                   hybrid.histogram, hybrid)
        cold_parts[sel] = np.asarray(cold)
        oob_flags[sel] = np.asarray(oobh)
        waste_parts[sel], pre_parts[sel], keep_parts[sel] = \
            _absolute_results(waste, last_t, prewarm, unload_at, t0,
                              duration, include_trailing)
    result = SimResult(cold_parts, counts.astype(np.int64), waste_parts,
                       pre_parts, keep_parts)
    if hybrid.use_arima and oob_flags.any():
        policy = HybridHistogramPolicy(hybrid)
        arima_idx = np.where(oob_flags)[0]
        scalar = simulate_scalar(trace, policy, include_trailing, arima_idx)
        result.cold[arima_idx] = scalar.cold[arima_idx]
        result.wasted_minutes[arima_idx] = scalar.wasted_minutes[arima_idx]
        result.final_prewarm[arima_idx] = scalar.final_prewarm[arima_idx]
        result.final_keep_alive[arima_idx] = scalar.final_keep_alive[arima_idx]
    return result


def simulate(trace: Trace, policy, include_trailing: bool = True) -> SimResult:
    """Dispatch: vectorized engines for the known policies, scalar otherwise."""
    if isinstance(policy, FixedKeepAlivePolicy):
        return simulate_fixed_batch(trace, policy.keep_alive, include_trailing)
    if isinstance(policy, HybridHistogramPolicy):
        return simulate_hybrid_batch(trace, policy.cfg, include_trailing)
    if isinstance(policy, HybridConfig):
        return simulate_hybrid_batch(trace, policy, include_trailing)
    return simulate_scalar(trace, policy, include_trailing)
