"""Trace-driven cold-start simulator (Section 5.1 of the paper).

Three interchangeable engines:

  * :func:`simulate_scalar` — event-driven reference. Walks each app's
    invocation sequence, querying any :class:`repro.core.policy.Policy`
    (including the full hybrid policy with its ARIMA path). This is the
    float64 oracle and handles arbitrary policies.

  * :func:`simulate_hybrid_batch` / :func:`simulate_fixed_batch` — vectorized
    JAX engines: all apps advance together through a ``lax.scan`` over padded
    event indices. The hybrid engine carries *cumulative* per-app bin counts
    (``[n_apps, n_bins]``, narrowest integer dtype the bucket's event count
    allows) so a step's histogram update is a suffix add and the head/tail
    percentile decision is a binary search — no fleet-wide cumsum recompute
    per step. Apps are bucketed by event count so a handful of very chatty
    apps do not inflate the scan length for everyone, and each bucket is
    further chunked over apps with double-buffered host→device transfer so
    ~1M-app traces fit in device memory. Time state is float64 end to end,
    matching the scalar oracle exactly at keep-alive boundaries. ARIMA cannot
    run inside a scan; apps whose out-of-bounds fraction crosses the
    threshold are re-simulated through the scalar engine and their results
    overridden (the paper: these are ~0.7% of invocations).

  * On TPU the fused step runs as a Pallas kernel
    (:func:`repro.kernels.histogram.fused_hybrid_step_pallas`) in float32;
    pass ``use_pallas=True`` to exercise it in interpret mode elsewhere.

The pre-PR batched engine (per-step full-matrix cumsum + argmax) is kept as
``simulate_hybrid_batch_reference`` — it is the regression baseline for the
``benchmarks/policy_overhead.py`` step-throughput comparison.

Exactly as in the paper, function execution time is simulated as 0 (so idle
time == inter-arrival time) to account wasted memory time conservatively, and
the first invocation of every app is a cold start.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from .histogram import (HistogramConfig, HistogramState, cum_record_idle_times,
                        find_first_ge)
from .policy import (FixedKeepAlivePolicy, HybridConfig, HybridHistogramPolicy,
                     Policy, PolicyWindows, is_warm, loaded_idle_time)
from .workload import Trace

__all__ = [
    "SimResult", "simulate_scalar", "simulate_fixed_batch",
    "simulate_hybrid_batch", "simulate_hybrid_batch_reference", "simulate",
    "BUCKET_EDGES", "DEFAULT_APP_CHUNK",
]

BUCKET_EDGES = (64, 512, 4096, 1 << 62)

# Apps per device-resident chunk of the hybrid scan: bounds the cumulative
# count state ([chunk, n_bins]) regardless of fleet size.
DEFAULT_APP_CHUNK = 131072


@dataclasses.dataclass
class SimResult:
    cold: np.ndarray            # [n_apps] cold-start counts
    invocations: np.ndarray     # [n_apps] invocation counts
    wasted_minutes: np.ndarray  # [n_apps] loaded-but-idle memory time

    @property
    def cold_pct(self) -> np.ndarray:
        return 100.0 * self.cold / np.maximum(self.invocations, 1)

    def cold_pct_percentile(self, q: float = 75.0) -> float:
        return float(np.percentile(self.cold_pct, q))

    @property
    def total_wasted(self) -> float:
        return float(self.wasted_minutes.sum())

    @property
    def always_cold_fraction(self) -> float:
        # Only apps that were actually invoked can be always-cold; apps with
        # zero invocations trivially satisfy cold >= invocations (0 >= 0) and
        # must not inflate the fraction (paper Fig. 12 counts invoked apps).
        invoked = self.invocations > 0
        if not invoked.any():
            return 0.0
        return float(np.mean(self.cold[invoked] >= self.invocations[invoked]))


# --------------------------------------------------------------------------
# Scalar reference engine
# --------------------------------------------------------------------------

def simulate_scalar(trace: Trace, policy: Policy,
                    include_trailing: bool = True,
                    app_indices: Optional[Sequence[int]] = None) -> SimResult:
    idx = range(trace.n_apps) if app_indices is None else app_indices
    n = trace.n_apps
    cold = np.zeros(n, np.int64)
    inv = np.zeros(n, np.int64)
    waste = np.zeros(n, np.float64)
    for i in idx:
        t = trace.events(i)
        app = trace.app_id(i)
        inv[i] = len(t)
        if len(t) == 0:
            continue
        cold[i] += 1  # first invocation is always cold
        w = policy.on_invocation(app, None)
        for k in range(1, len(t)):
            it = float(t[k]) - float(t[k - 1])  # exec time = 0 => IT == IAT
            if not is_warm(it, w):
                cold[i] += 1
            waste[i] += loaded_idle_time(it, w)
            w = policy.on_invocation(app, it)
        if include_trailing:
            tail_gap = trace.duration_minutes - float(t[-1])
            waste[i] += loaded_idle_time(tail_gap, w) if tail_gap > 0 else 0.0
    return SimResult(cold, inv, waste)


# --------------------------------------------------------------------------
# Vectorized JAX engines
# --------------------------------------------------------------------------

def _fixed_step(keep_alive, carry, t_now):
    prev_t, cold, waste = carry
    valid = jnp.isfinite(t_now)
    it = t_now - prev_t
    first = ~jnp.isfinite(prev_t)
    is_cold = valid & (first | (it > keep_alive))
    gap_waste = jnp.where(valid & ~first, jnp.minimum(it, keep_alive), 0.0)
    new_prev = jnp.where(valid, t_now, prev_t)
    return (new_prev, cold + is_cold, waste + gap_waste), None


@partial(jax.jit, static_argnums=(3,))
def _fixed_scan(times, keep_alive, duration, include_trailing: bool):
    n = times.shape[0]
    tdtype = times.dtype
    init = (jnp.full((n,), -jnp.inf, tdtype),
            jnp.zeros((n,), jnp.int32), jnp.zeros((n,), tdtype))
    (last_t, cold, waste), _ = jax.lax.scan(
        partial(_fixed_step, keep_alive), init, times.T)
    if include_trailing:
        tail = jnp.maximum(duration - last_t, 0.0)
        waste = waste + jnp.where(jnp.isfinite(last_t),
                                  jnp.minimum(tail, keep_alive), 0.0)
    return cold, waste


def simulate_fixed_batch(trace: Trace, keep_alive_minutes: float,
                         include_trailing: bool = True) -> SimResult:
    times, counts = trace.to_padded()
    cold_parts = np.zeros(trace.n_apps, np.int64)
    waste_parts = np.zeros(trace.n_apps, np.float64)
    # float64 time state: two-week traces (t ~ 2e4 minutes) lose the
    # sub-millisecond IAT bits in float32, flipping warm/cold verdicts
    # exactly at the keep-alive boundary vs the scalar oracle.
    with enable_x64():
        for sel, sub in _buckets(times, counts):
            cold, waste = _fixed_scan(jnp.asarray(sub, jnp.float64),
                                      jnp.float64(keep_alive_minutes),
                                      jnp.float64(trace.duration_minutes),
                                      include_trailing)
            cold_parts[sel] = np.asarray(cold)
            waste_parts[sel] = np.asarray(waste)
    return SimResult(cold_parts, counts.astype(np.int64), waste_parts)


def _buckets(times: np.ndarray, counts: np.ndarray):
    """Yield (app_index_array, trimmed_times) grouped by event count."""
    lo = 0
    for edge in BUCKET_EDGES:
        sel = np.where((counts > lo) & (counts <= edge))[0]
        if len(sel):
            width = int(counts[sel].max())
            yield sel, times[sel][:, :width]
        lo = edge


def _chunked_buckets(times: np.ndarray, counts: np.ndarray, app_chunk: int):
    """Bucket by event count, then chunk each bucket over apps."""
    for sel, sub in _buckets(times, counts):
        for lo in range(0, len(sel), app_chunk):
            yield sel[lo:lo + app_chunk], sub[lo:lo + app_chunk]


# -- hybrid ------------------------------------------------------------------


def _cum_dtype_for(width: int):
    """Narrowest cum-count dtype for a bucket scanning ``width`` events.

    Per-app cumulative counts are bounded by the bucket's scan length, so
    short-trace buckets (the overwhelming majority of a realistic fleet) can
    carry int8/int16 state — the suffix add over [n_apps, n_bins] is the
    bandwidth hot spot of the whole simulation.
    """
    if width <= 127:
        return jnp.int8
    if width <= 32766:
        return jnp.int16
    return jnp.int32


def _fused_hybrid_step(cfg: HistogramConfig, hybrid: HybridConfig, carry,
                       t_now):
    """Fused scan step: warm/cold + waste accounting, histogram suffix-add
    update, Welford CV accumulation, and the head/tail percentile window
    decision — one pass, no per-step cumsum (jnp path; the Pallas twin is
    ``repro.kernels.histogram.fused_hybrid_step_pallas``)."""
    (prev_t, cum, oob, cv_sum, cv_sum_sq, prewarm, keep, cold, waste) = carry
    n_bins = cfg.n_bins
    wdtype = t_now.dtype
    valid = jnp.isfinite(t_now)
    first = ~jnp.isfinite(prev_t)
    it = t_now - prev_t

    # Warm/cold under the windows decided after the previous invocation.
    warm = jnp.where(prewarm <= 0.0, it <= keep,
                     (it >= prewarm) & (it <= prewarm + keep))
    is_cold = valid & (first | ~warm)

    # Wasted loaded-idle time for the gap that just closed.
    gap_w_nopre = jnp.minimum(it, keep)
    gap_w_pre = jnp.where(it < prewarm, 0.0,
                          jnp.minimum(it, prewarm + keep) - prewarm)
    gap_waste = jnp.where(valid & ~first,
                          jnp.where(prewarm <= 0.0, gap_w_nopre, gap_w_pre),
                          0.0)

    # Record the idle time into the cumulative histogram state.
    rec = valid & ~first
    cum, old, in_b, oob_hit = cum_record_idle_times(cum, it, rec, cfg)
    total = cum[:, -1].astype(jnp.int32)
    oob = oob + oob_hit.astype(jnp.int32)
    inb = in_b.astype(cv_sum.dtype)
    cv_sum = cv_sum + inb
    cv_sum_sq = cv_sum_sq + inb * (2.0 * old.astype(cv_sum.dtype) + 1.0)

    # Representativeness check (CV of bin counts), in the time dtype so the
    # float64 path reproduces the scalar oracle's decisions bit-for-bit.
    mean = cv_sum.astype(wdtype) / n_bins
    var = jnp.maximum(cv_sum_sq.astype(wdtype) / n_bins - mean * mean, 0.0)
    cv = jnp.where(mean > 0, jnp.sqrt(var) / jnp.maximum(mean, 1e-9), 0.0)

    # Percentile windows off the maintained cumulative counts.
    tot_f = total.astype(wdtype)
    head_thr = jnp.maximum(jnp.ceil(tot_f * (cfg.head_percentile / 100.0)),
                           1.0).astype(jnp.int32)
    tail_thr = jnp.maximum(jnp.ceil(tot_f * (cfg.tail_percentile / 100.0)),
                           1.0).astype(jnp.int32)
    head_bin = find_first_ge(cum, head_thr)
    tail_bin = find_first_ge(cum, tail_thr) + 1

    new_pre = head_bin.astype(wdtype) * cfg.bin_minutes * (1.0 - cfg.margin)
    tail = jnp.minimum(tail_bin.astype(wdtype) * cfg.bin_minutes,
                       cfg.range_minutes) * (1.0 + cfg.margin)
    new_keep = jnp.maximum(tail - new_pre, 0.0)

    seen = total + oob
    use_hist = ((seen >= hybrid.min_samples)
                & (cv >= hybrid.cv_threshold)
                & (total > 0)
                & ~(oob.astype(wdtype) > hybrid.oob_fraction_threshold
                    * jnp.maximum(seen, 1).astype(wdtype)))
    new_pre = jnp.where(use_hist, new_pre, 0.0)
    new_keep = jnp.where(use_hist, new_keep,
                         jnp.asarray(hybrid.standard_keep_alive, wdtype))

    # Decide windows for the next gap (for apps that just saw an event).
    prewarm = jnp.where(valid, new_pre, prewarm)
    keep = jnp.where(valid, new_keep, keep)
    prev_t = jnp.where(valid, t_now, prev_t)
    return (prev_t, cum, oob, cv_sum, cv_sum_sq, prewarm, keep,
            cold + is_cold, waste + gap_waste), None


def _trailing_waste(last_t, duration, prewarm, keep, waste):
    tail_gap = jnp.maximum(duration - last_t, 0.0)
    t_nopre = jnp.minimum(tail_gap, keep)
    t_pre = jnp.where(tail_gap < prewarm, 0.0,
                      jnp.minimum(tail_gap, prewarm + keep) - prewarm)
    return waste + jnp.where(jnp.isfinite(last_t),
                             jnp.where(prewarm <= 0.0, t_nopre, t_pre), 0.0)


@partial(jax.jit, static_argnums=(2, 3, 4, 5))
def _hybrid_scan(times, duration, cfg: HistogramConfig, hybrid: HybridConfig,
                 include_trailing: bool, cum_dtype=jnp.int32):
    n = times.shape[0]
    tdtype = times.dtype
    init = (
        jnp.full((n,), -jnp.inf, tdtype),
        jnp.zeros((n, cfg.n_bins), cum_dtype),
        jnp.zeros((n,), jnp.int32),
        jnp.zeros((n,), tdtype),                                      # cv_sum
        jnp.zeros((n,), tdtype),                                      # cv_sum_sq
        jnp.zeros((n,), tdtype),                                      # prewarm
        jnp.full((n,), hybrid.standard_keep_alive, tdtype),           # keep
        jnp.zeros((n,), jnp.int32),
        jnp.zeros((n,), tdtype),
    )
    carry, _ = jax.lax.scan(partial(_fused_hybrid_step, cfg, hybrid), init,
                            times.T)
    (last_t, cum, oob, _, _, prewarm, keep, cold, waste) = carry
    total = cum[:, -1].astype(jnp.int32)
    if include_trailing:
        waste = _trailing_waste(last_t, duration, prewarm, keep, waste)
    oob_heavy = oob.astype(jnp.float32) > (
        jnp.maximum(total + oob, 1).astype(jnp.float32)
        * jnp.float32(hybrid.oob_fraction_threshold))
    return cold, waste, oob_heavy


@partial(jax.jit, static_argnums=(2, 3, 4, 5, 6))
def _hybrid_scan_pallas(times, duration, cfg: HistogramConfig,
                        hybrid: HybridConfig, include_trailing: bool,
                        interpret: bool = True, tile_apps: int = 512):
    """Same fused scan, stepping through the Pallas TPU kernel (float32)."""
    from ..kernels.histogram import fused_hybrid_step_pallas

    # Pad the app dimension to the kernel tile ONCE, outside the scan —
    # otherwise the kernel wrapper re-pads and re-slices the whole carry
    # (including [n, n_bins] cum) on every scan step. Padded rows carry
    # t = +inf and are never active.
    n_real = times.shape[0]
    pad = (-n_real) % min(tile_apps, n_real) if n_real else 0
    if pad:
        times = jnp.concatenate(
            [times, jnp.full((pad, times.shape[1]), jnp.inf, times.dtype)])
    n = times.shape[0]
    init = (
        jnp.full((n,), -jnp.inf, jnp.float32),
        jnp.zeros((n, cfg.n_bins), jnp.int32),
        jnp.zeros((n,), jnp.int32),
        jnp.zeros((n,), jnp.float32),
        jnp.zeros((n,), jnp.float32),
        jnp.zeros((n,), jnp.float32),
        jnp.full((n,), jnp.float32(hybrid.standard_keep_alive)),
        jnp.zeros((n,), jnp.int32),
        jnp.zeros((n,), jnp.float32),
    )

    def step(carry, t_now):
        out = fused_hybrid_step_pallas(
            t_now, *carry,
            head_pct=cfg.head_percentile, tail_pct=cfg.tail_percentile,
            margin=cfg.margin, bin_minutes=cfg.bin_minutes,
            range_minutes=cfg.range_minutes,
            cv_threshold=hybrid.cv_threshold,
            min_samples=hybrid.min_samples,
            oob_threshold=hybrid.oob_fraction_threshold,
            standard_keep=hybrid.standard_keep_alive,
            tile_apps=tile_apps, interpret=interpret)
        return out, None

    carry, _ = jax.lax.scan(step, init, times.T)
    carry = tuple(c[:n_real] for c in carry)
    (last_t, cum, oob, _, _, prewarm, keep, cold, waste) = carry
    total = cum[:, -1]
    if include_trailing:
        waste = _trailing_waste(last_t, duration, prewarm, keep, waste)
    oob_heavy = oob.astype(jnp.float32) > (
        jnp.maximum(total + oob, 1).astype(jnp.float32)
        * jnp.float32(hybrid.oob_fraction_threshold))
    return cold, waste, oob_heavy


def simulate_hybrid_batch(trace: Trace, hybrid: HybridConfig,
                          include_trailing: bool = True, *,
                          app_chunk: Optional[int] = None,
                          use_pallas: Optional[bool] = None) -> SimResult:
    """Vectorized hybrid simulation + scalar post-pass for ARIMA apps.

    Buckets apps by event count, chunks each bucket to ``app_chunk`` apps
    (bounding device state), and streams chunks with the next host→device
    transfer overlapping the current chunk's scan. ``use_pallas`` defaults
    to True on TPU (float32 fused kernel) and False elsewhere (float64 jnp
    fused step, exact vs the scalar oracle). Caveat: TPUs have no float64,
    so the Pallas path can flip warm/cold verdicts that land exactly on a
    keep-alive boundary once trace times outgrow float32 (t ~ 2e4 minutes);
    pass ``use_pallas=False`` when oracle-exact counts matter more than
    throughput.
    """
    times, counts = trace.to_padded()
    n = trace.n_apps
    cold_parts = np.zeros(n, np.int64)
    waste_parts = np.zeros(n, np.float64)
    oob_flags = np.zeros(n, bool)
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    chunk = int(app_chunk) if app_chunk else DEFAULT_APP_CHUNK
    cfg = hybrid.histogram

    def run_all(run_dtype, scan_fn):
        # Streaming with a one-chunk lookahead: at most two chunk copies are
        # alive at once (the one scanning and the one whose host->device
        # transfer is enqueued ahead of blocking on the current result).
        work = _chunked_buckets(times, counts, chunk)
        pending = next(work, None)
        if pending is None:
            return
        pending = (pending[0],
                   jax.device_put(np.ascontiguousarray(pending[1], run_dtype)))
        while pending is not None:
            sel, cur = pending
            nxt = next(work, None)
            pending = None if nxt is None else (
                nxt[0], jax.device_put(np.ascontiguousarray(nxt[1], run_dtype)))
            cold, waste, oobh = scan_fn(cur)
            cold_parts[sel] = np.asarray(cold)
            waste_parts[sel] = np.asarray(waste)
            oob_flags[sel] = np.asarray(oobh)

    if use_pallas:
        from ..kernels import ops
        run_all(np.float32, lambda cur: _hybrid_scan_pallas(
            cur, jnp.float32(trace.duration_minutes), cfg, hybrid,
            include_trailing, ops.INTERPRET))
    else:
        with enable_x64():
            run_all(np.float64, lambda cur: _hybrid_scan(
                cur, jnp.float64(trace.duration_minutes), cfg, hybrid,
                include_trailing, _cum_dtype_for(cur.shape[1])))
    result = SimResult(cold_parts, counts.astype(np.int64), waste_parts)
    if hybrid.use_arima and oob_flags.any():
        # Re-simulate OOB-heavy apps with the full scalar policy (ARIMA path).
        policy = HybridHistogramPolicy(hybrid)
        arima_idx = np.where(oob_flags)[0]
        scalar = simulate_scalar(trace, policy, include_trailing, arima_idx)
        result.cold[arima_idx] = scalar.cold[arima_idx]
        result.wasted_minutes[arima_idx] = scalar.wasted_minutes[arima_idx]
    return result


# -- pre-PR batched engine (benchmark/regression baseline) -------------------


def _hybrid_windows_reference(counts, total, oob, cv_sum, cv_sum_sq,
                              cfg: HistogramConfig, hybrid: HybridConfig):
    """Vectorized decision tree (ARIMA branch resolved to standard keep-alive;
    ARIMA apps are post-processed by the scalar engine)."""
    n_bins = cfg.n_bins
    seen = total + oob
    mean = cv_sum / n_bins
    var = jnp.maximum(cv_sum_sq / n_bins - mean * mean, 0.0)
    cv = jnp.where(mean > 0, jnp.sqrt(var) / jnp.maximum(mean, 1e-9), 0.0)

    cum = jnp.cumsum(counts, axis=-1)
    tot_f = jnp.maximum(total, 1).astype(jnp.float32)
    head_thr = jnp.ceil(tot_f * (cfg.head_percentile / 100.0)).astype(jnp.int32)
    tail_thr = jnp.ceil(tot_f * (cfg.tail_percentile / 100.0)).astype(jnp.int32)
    head_bin = jnp.argmax(cum >= jnp.maximum(head_thr, 1)[:, None], axis=-1)
    tail_bin = jnp.argmax(cum >= jnp.maximum(tail_thr, 1)[:, None], axis=-1) + 1

    prewarm = head_bin.astype(jnp.float32) * cfg.bin_minutes * (1.0 - cfg.margin)
    tail = jnp.minimum(tail_bin.astype(jnp.float32) * cfg.bin_minutes,
                       cfg.range_minutes) * (1.0 + cfg.margin)
    keep = jnp.maximum(tail - prewarm, 0.0)

    use_hist = ((seen >= hybrid.min_samples)
                & (cv >= hybrid.cv_threshold)
                & (total > 0)
                & ~(oob.astype(jnp.float32) > hybrid.oob_fraction_threshold
                    * jnp.maximum(seen, 1).astype(jnp.float32)))
    std_keep = jnp.float32(hybrid.standard_keep_alive)
    prewarm = jnp.where(use_hist, prewarm, 0.0)
    keep = jnp.where(use_hist, keep, std_keep)
    return prewarm, keep


def _hybrid_step_reference(cfg: HistogramConfig, hybrid: HybridConfig, carry,
                           t_now):
    (prev_t, counts, total, oob, cv_sum, cv_sum_sq, prewarm, keep,
     cold, waste) = carry
    n_bins = cfg.n_bins
    valid = jnp.isfinite(t_now)
    first = ~jnp.isfinite(prev_t)
    it = t_now - prev_t

    warm = jnp.where(prewarm <= 0.0, it <= keep,
                     (it >= prewarm) & (it <= prewarm + keep))
    is_cold = valid & (first | ~warm)

    gap_w_nopre = jnp.minimum(it, keep)
    gap_w_pre = jnp.where(it < prewarm, 0.0,
                          jnp.minimum(it, prewarm + keep) - prewarm)
    gap_waste = jnp.where(valid & ~first,
                          jnp.where(prewarm <= 0.0, gap_w_nopre, gap_w_pre), 0.0)

    rec = valid & ~first
    bin_idx = jnp.floor(it / cfg.bin_minutes).astype(jnp.int32)
    in_b = rec & (bin_idx >= 0) & (bin_idx < n_bins)
    oob_hit = rec & (bin_idx >= n_bins)
    safe = jnp.clip(bin_idx, 0, n_bins - 1)
    napps = counts.shape[0]
    rows = jnp.arange(napps)
    old = counts[rows, safe]
    counts = counts.at[rows, safe].add(in_b.astype(jnp.int32))
    total = total + in_b.astype(jnp.int32)
    oob = oob + oob_hit.astype(jnp.int32)
    inb = in_b.astype(jnp.float32)
    cv_sum = cv_sum + inb
    cv_sum_sq = cv_sum_sq + inb * (2.0 * old.astype(jnp.float32) + 1.0)

    new_pre, new_keep = _hybrid_windows_reference(counts, total, oob, cv_sum,
                                                  cv_sum_sq, cfg, hybrid)
    prewarm = jnp.where(valid, new_pre, prewarm)
    keep = jnp.where(valid, new_keep, keep)
    prev_t = jnp.where(valid, t_now, prev_t)
    return (prev_t, counts, total, oob, cv_sum, cv_sum_sq, prewarm, keep,
            cold + is_cold, waste + gap_waste), None


@partial(jax.jit, static_argnums=(2, 3, 4))
def _hybrid_scan_reference(times, duration, cfg: HistogramConfig,
                           hybrid: HybridConfig, include_trailing: bool):
    n = times.shape[0]
    n_bins = cfg.n_bins
    init = (
        jnp.full((n,), -jnp.inf, jnp.float32),
        jnp.zeros((n, n_bins), jnp.int32),
        jnp.zeros((n,), jnp.int32),
        jnp.zeros((n,), jnp.int32),
        jnp.zeros((n,), jnp.float32),
        jnp.zeros((n,), jnp.float32),
        jnp.zeros((n,), jnp.float32),                                 # prewarm
        jnp.full((n,), jnp.float32(hybrid.standard_keep_alive)),      # keep
        jnp.zeros((n,), jnp.int32),
        jnp.zeros((n,), jnp.float32),
    )
    carry, _ = jax.lax.scan(partial(_hybrid_step_reference, cfg, hybrid),
                            init, times.T)
    (last_t, counts, total, oob, _, _, prewarm, keep, cold, waste) = carry
    if include_trailing:
        waste = _trailing_waste(last_t, duration, prewarm, keep, waste)
    oob_heavy = oob.astype(jnp.float32) > (
        jnp.maximum(total + oob, 1).astype(jnp.float32)
        * jnp.float32(hybrid.oob_fraction_threshold))
    return cold, waste, oob_heavy


def simulate_hybrid_batch_reference(trace: Trace, hybrid: HybridConfig,
                                    include_trailing: bool = True) -> SimResult:
    """Pre-PR batched hybrid engine (float32, per-step cumsum recompute)."""
    times, counts = trace.to_padded()
    n = trace.n_apps
    cold_parts = np.zeros(n, np.int64)
    waste_parts = np.zeros(n, np.float64)
    oob_flags = np.zeros(n, bool)
    for sel, sub in _buckets(times, counts):
        cold, waste, oobh = _hybrid_scan_reference(
            jnp.asarray(sub, jnp.float32),
            jnp.float32(trace.duration_minutes),
            hybrid.histogram, hybrid, include_trailing)
        cold_parts[sel] = np.asarray(cold)
        waste_parts[sel] = np.asarray(waste)
        oob_flags[sel] = np.asarray(oobh)
    result = SimResult(cold_parts, counts.astype(np.int64), waste_parts)
    if hybrid.use_arima and oob_flags.any():
        policy = HybridHistogramPolicy(hybrid)
        arima_idx = np.where(oob_flags)[0]
        scalar = simulate_scalar(trace, policy, include_trailing, arima_idx)
        result.cold[arima_idx] = scalar.cold[arima_idx]
        result.wasted_minutes[arima_idx] = scalar.wasted_minutes[arima_idx]
    return result


def simulate(trace: Trace, policy, include_trailing: bool = True) -> SimResult:
    """Dispatch: vectorized engines for the known policies, scalar otherwise."""
    if isinstance(policy, FixedKeepAlivePolicy):
        return simulate_fixed_batch(trace, policy.keep_alive, include_trailing)
    if isinstance(policy, HybridHistogramPolicy):
        return simulate_hybrid_batch(trace, policy.cfg, include_trailing)
    if isinstance(policy, HybridConfig):
        return simulate_hybrid_batch(trace, policy, include_trailing)
    return simulate_scalar(trace, policy, include_trailing)
