"""Trace-driven cold-start simulator (Section 5.1 of the paper).

Two interchangeable engines:

  * :func:`simulate_scalar` — event-driven reference. Walks each app's
    invocation sequence, querying any :class:`repro.core.policy.Policy`
    (including the full hybrid policy with its ARIMA path). This is the
    oracle and handles arbitrary policies.

  * :func:`simulate_hybrid_batch` / :func:`simulate_fixed_batch` — vectorized
    JAX engines: all apps advance together through a ``lax.scan`` over padded
    event indices, carrying the batched histogram state
    (``[n_apps, n_bins]``). Apps are bucketed by event count so a handful of
    very chatty apps do not inflate the scan length for everyone. ARIMA
    cannot run inside a scan; apps whose out-of-bounds fraction crosses the
    threshold are re-simulated through the scalar engine and their results
    overridden (the paper: these are ~0.7% of invocations).

Exactly as in the paper, function execution time is simulated as 0 (so idle
time == inter-arrival time) to account wasted memory time conservatively, and
the first invocation of every app is a cold start.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .histogram import HistogramConfig, HistogramState
from .policy import (FixedKeepAlivePolicy, HybridConfig, HybridHistogramPolicy,
                     Policy, PolicyWindows, is_warm, loaded_idle_time)
from .workload import Trace

__all__ = [
    "SimResult", "simulate_scalar", "simulate_fixed_batch",
    "simulate_hybrid_batch", "simulate", "BUCKET_EDGES",
]

BUCKET_EDGES = (64, 512, 4096, 1 << 62)


@dataclasses.dataclass
class SimResult:
    cold: np.ndarray            # [n_apps] cold-start counts
    invocations: np.ndarray     # [n_apps] invocation counts
    wasted_minutes: np.ndarray  # [n_apps] loaded-but-idle memory time

    @property
    def cold_pct(self) -> np.ndarray:
        return 100.0 * self.cold / np.maximum(self.invocations, 1)

    def cold_pct_percentile(self, q: float = 75.0) -> float:
        return float(np.percentile(self.cold_pct, q))

    @property
    def total_wasted(self) -> float:
        return float(self.wasted_minutes.sum())

    @property
    def always_cold_fraction(self) -> float:
        return float(np.mean(self.cold >= self.invocations))


# --------------------------------------------------------------------------
# Scalar reference engine
# --------------------------------------------------------------------------

def simulate_scalar(trace: Trace, policy: Policy,
                    include_trailing: bool = True,
                    app_indices: Optional[Sequence[int]] = None) -> SimResult:
    idx = range(trace.n_apps) if app_indices is None else app_indices
    n = trace.n_apps
    cold = np.zeros(n, np.int64)
    inv = np.zeros(n, np.int64)
    waste = np.zeros(n, np.float64)
    for i in idx:
        t = trace.times[i]
        app = trace.specs[i].app_id
        inv[i] = len(t)
        if len(t) == 0:
            continue
        cold[i] += 1  # first invocation is always cold
        w = policy.on_invocation(app, None)
        for k in range(1, len(t)):
            it = float(t[k] - t[k - 1])  # exec time = 0 => IT == IAT
            if not is_warm(it, w):
                cold[i] += 1
            waste[i] += loaded_idle_time(it, w)
            w = policy.on_invocation(app, it)
        if include_trailing:
            tail_gap = trace.duration_minutes - float(t[-1])
            waste[i] += loaded_idle_time(tail_gap, w) if tail_gap > 0 else 0.0
    return SimResult(cold, inv, waste)


# --------------------------------------------------------------------------
# Vectorized JAX engines
# --------------------------------------------------------------------------

def _fixed_step(keep_alive, carry, t_now):
    prev_t, cold, waste = carry
    valid = jnp.isfinite(t_now)
    it = t_now - prev_t
    first = ~jnp.isfinite(prev_t)
    is_cold = valid & (first | (it > keep_alive))
    gap_waste = jnp.where(valid & ~first, jnp.minimum(it, keep_alive), 0.0)
    new_prev = jnp.where(valid, t_now, prev_t)
    return (new_prev, cold + is_cold, waste + gap_waste), None


@partial(jax.jit, static_argnums=(3,))
def _fixed_scan(times, keep_alive, duration, include_trailing: bool):
    n = times.shape[0]
    init = (jnp.full((n,), -jnp.inf, jnp.float32),
            jnp.zeros((n,), jnp.int32), jnp.zeros((n,), jnp.float32))
    (last_t, cold, waste), _ = jax.lax.scan(
        partial(_fixed_step, keep_alive), init, times.T)
    if include_trailing:
        tail = jnp.maximum(duration - last_t, 0.0)
        waste = waste + jnp.where(jnp.isfinite(last_t),
                                  jnp.minimum(tail, keep_alive), 0.0)
    return cold, waste


def simulate_fixed_batch(trace: Trace, keep_alive_minutes: float,
                         include_trailing: bool = True) -> SimResult:
    times, counts = trace.to_padded()
    cold_parts = np.zeros(trace.n_apps, np.int64)
    waste_parts = np.zeros(trace.n_apps, np.float64)
    for sel, sub in _buckets(times, counts):
        cold, waste = _fixed_scan(jnp.asarray(sub),
                                  jnp.float32(keep_alive_minutes),
                                  jnp.float32(trace.duration_minutes),
                                  include_trailing)
        cold_parts[sel] = np.asarray(cold)
        waste_parts[sel] = np.asarray(waste)
    return SimResult(cold_parts, counts.astype(np.int64), waste_parts)


def _buckets(times: np.ndarray, counts: np.ndarray):
    """Yield (app_index_array, trimmed_times) grouped by event count."""
    lo = 0
    for edge in BUCKET_EDGES:
        sel = np.where((counts > lo) & (counts <= edge))[0]
        if len(sel):
            width = int(counts[sel].max())
            yield sel, times[sel][:, :width]
        lo = edge


# -- hybrid ------------------------------------------------------------------


def _hybrid_windows(counts, total, oob, cv_sum, cv_sum_sq, cfg: HistogramConfig,
                    hybrid: HybridConfig):
    """Vectorized decision tree (ARIMA branch resolved to standard keep-alive;
    ARIMA apps are post-processed by the scalar engine)."""
    n_bins = cfg.n_bins
    seen = total + oob
    mean = cv_sum / n_bins
    var = jnp.maximum(cv_sum_sq / n_bins - mean * mean, 0.0)
    cv = jnp.where(mean > 0, jnp.sqrt(var) / jnp.maximum(mean, 1e-9), 0.0)

    cum = jnp.cumsum(counts, axis=-1)
    tot_f = jnp.maximum(total, 1).astype(jnp.float32)
    head_thr = jnp.ceil(tot_f * (cfg.head_percentile / 100.0)).astype(jnp.int32)
    tail_thr = jnp.ceil(tot_f * (cfg.tail_percentile / 100.0)).astype(jnp.int32)
    head_bin = jnp.argmax(cum >= jnp.maximum(head_thr, 1)[:, None], axis=-1)
    tail_bin = jnp.argmax(cum >= jnp.maximum(tail_thr, 1)[:, None], axis=-1) + 1

    prewarm = head_bin.astype(jnp.float32) * cfg.bin_minutes * (1.0 - cfg.margin)
    tail = jnp.minimum(tail_bin.astype(jnp.float32) * cfg.bin_minutes,
                       cfg.range_minutes) * (1.0 + cfg.margin)
    keep = jnp.maximum(tail - prewarm, 0.0)

    use_hist = ((seen >= hybrid.min_samples)
                & (cv >= hybrid.cv_threshold)
                & (total > 0)
                & ~(oob.astype(jnp.float32) > hybrid.oob_fraction_threshold
                    * jnp.maximum(seen, 1).astype(jnp.float32)))
    std_keep = jnp.float32(hybrid.standard_keep_alive)
    prewarm = jnp.where(use_hist, prewarm, 0.0)
    keep = jnp.where(use_hist, keep, std_keep)
    return prewarm, keep


def _hybrid_step(cfg: HistogramConfig, hybrid: HybridConfig, carry, t_now):
    (prev_t, counts, total, oob, cv_sum, cv_sum_sq, prewarm, keep,
     cold, waste) = carry
    n_bins = cfg.n_bins
    valid = jnp.isfinite(t_now)
    first = ~jnp.isfinite(prev_t)
    it = t_now - prev_t

    # Warm/cold under the windows decided after the previous invocation.
    warm = jnp.where(prewarm <= 0.0, it <= keep,
                     (it >= prewarm) & (it <= prewarm + keep))
    is_cold = valid & (first | ~warm)

    # Wasted loaded-idle time for the gap that just closed.
    gap_w_nopre = jnp.minimum(it, keep)
    gap_w_pre = jnp.where(it < prewarm, 0.0,
                          jnp.minimum(it, prewarm + keep) - prewarm)
    gap_waste = jnp.where(valid & ~first,
                          jnp.where(prewarm <= 0.0, gap_w_nopre, gap_w_pre), 0.0)

    # Record the idle time into the histogram state.
    rec = valid & ~first
    bin_idx = jnp.floor(it / cfg.bin_minutes).astype(jnp.int32)
    in_b = rec & (bin_idx >= 0) & (bin_idx < n_bins)
    oob_hit = rec & (bin_idx >= n_bins)
    safe = jnp.clip(bin_idx, 0, n_bins - 1)
    napps = counts.shape[0]
    rows = jnp.arange(napps)
    old = counts[rows, safe]
    counts = counts.at[rows, safe].add(in_b.astype(jnp.int32))
    total = total + in_b.astype(jnp.int32)
    oob = oob + oob_hit.astype(jnp.int32)
    inb = in_b.astype(jnp.float32)
    cv_sum = cv_sum + inb
    cv_sum_sq = cv_sum_sq + inb * (2.0 * old.astype(jnp.float32) + 1.0)

    # Decide windows for the next gap (for apps that just saw an event).
    new_pre, new_keep = _hybrid_windows(counts, total, oob, cv_sum, cv_sum_sq,
                                        cfg, hybrid)
    prewarm = jnp.where(valid, new_pre, prewarm)
    keep = jnp.where(valid, new_keep, keep)
    prev_t = jnp.where(valid, t_now, prev_t)
    return (prev_t, counts, total, oob, cv_sum, cv_sum_sq, prewarm, keep,
            cold + is_cold, waste + gap_waste), None


@partial(jax.jit, static_argnums=(2, 3, 4))
def _hybrid_scan(times, duration, cfg: HistogramConfig, hybrid: HybridConfig,
                 include_trailing: bool):
    n = times.shape[0]
    n_bins = cfg.n_bins
    init = (
        jnp.full((n,), -jnp.inf, jnp.float32),
        jnp.zeros((n, n_bins), jnp.int32),
        jnp.zeros((n,), jnp.int32),
        jnp.zeros((n,), jnp.int32),
        jnp.zeros((n,), jnp.float32),
        jnp.zeros((n,), jnp.float32),
        jnp.zeros((n,), jnp.float32),                                 # prewarm
        jnp.full((n,), jnp.float32(hybrid.standard_keep_alive)),      # keep
        jnp.zeros((n,), jnp.int32),
        jnp.zeros((n,), jnp.float32),
    )
    carry, _ = jax.lax.scan(partial(_hybrid_step, cfg, hybrid), init, times.T)
    (last_t, counts, total, oob, _, _, prewarm, keep, cold, waste) = carry
    if include_trailing:
        tail_gap = jnp.maximum(duration - last_t, 0.0)
        t_nopre = jnp.minimum(tail_gap, keep)
        t_pre = jnp.where(tail_gap < prewarm, 0.0,
                          jnp.minimum(tail_gap, prewarm + keep) - prewarm)
        waste = waste + jnp.where(jnp.isfinite(last_t),
                                  jnp.where(prewarm <= 0.0, t_nopre, t_pre), 0.0)
    oob_heavy = oob.astype(jnp.float32) > (
        jnp.maximum(total + oob, 1).astype(jnp.float32)
        * jnp.float32(hybrid.oob_fraction_threshold))
    return cold, waste, oob_heavy


def simulate_hybrid_batch(trace: Trace, hybrid: HybridConfig,
                          include_trailing: bool = True) -> SimResult:
    """Vectorized hybrid simulation + scalar post-pass for ARIMA apps."""
    times, counts = trace.to_padded()
    n = trace.n_apps
    cold_parts = np.zeros(n, np.int64)
    waste_parts = np.zeros(n, np.float64)
    oob_flags = np.zeros(n, bool)
    for sel, sub in _buckets(times, counts):
        cold, waste, oobh = _hybrid_scan(
            jnp.asarray(sub), jnp.float32(trace.duration_minutes),
            hybrid.histogram, hybrid, include_trailing)
        cold_parts[sel] = np.asarray(cold)
        waste_parts[sel] = np.asarray(waste)
        oob_flags[sel] = np.asarray(oobh)
    result = SimResult(cold_parts, counts.astype(np.int64), waste_parts)
    if hybrid.use_arima and oob_flags.any():
        # Re-simulate OOB-heavy apps with the full scalar policy (ARIMA path).
        policy = HybridHistogramPolicy(hybrid)
        arima_idx = np.where(oob_flags)[0]
        scalar = simulate_scalar(trace, policy, include_trailing, arima_idx)
        result.cold[arima_idx] = scalar.cold[arima_idx]
        result.wasted_minutes[arima_idx] = scalar.wasted_minutes[arima_idx]
    return result


def simulate(trace: Trace, policy, include_trailing: bool = True) -> SimResult:
    """Dispatch: vectorized engines for the known policies, scalar otherwise."""
    if isinstance(policy, FixedKeepAlivePolicy):
        return simulate_fixed_batch(trace, policy.keep_alive, include_trailing)
    if isinstance(policy, HybridHistogramPolicy):
        return simulate_hybrid_batch(trace, policy.cfg, include_trailing)
    if isinstance(policy, HybridConfig):
        return simulate_hybrid_batch(trace, policy, include_trailing)
    return simulate_scalar(trace, policy, include_trailing)
