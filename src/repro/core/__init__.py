"""Core: the paper's contribution — hybrid histogram cold-start policy.

All decision formulas are single-sourced in :mod:`repro.core.policy_math`;
everything else (scalar policy, batched engines, Pallas kernels, serving
warm pool) is representation-specific glue around those helpers. The
experiment front door is declarative on BOTH axes: policies are
``PolicySpec`` grids and workloads are ``WorkloadSpec`` scenarios, driven
through ``run()``/``sweep()`` (``sweep(traces=..., specs=...)`` for the
(T, S) grid).
"""
from . import policy_math
from .histogram import AppHistogram, HistogramConfig, HistogramState, init_state
from .policy import (FixedKeepAlivePolicy, HybridConfig, HybridHistogramPolicy,
                     NoUnloadingPolicy, Policy, PolicyWindows, is_warm,
                     loaded_idle_time)
from .simulator import SimResult, simulate_scalar
from .workload import AppSpec, Trace, generate_trace, sample_apps
from .workload_spec import (SCENARIOS, Cohort, WorkloadSpec, azure_like,
                            bursty, diurnal, flash_crowd, scenario,
                            timer_heavy, weekend_dip)
from .experiment import (ENGINES, EngineOptions, FixedSpec, HybridSpec,
                         NoUnloadSpec, PolicySpec, SweepGrid, SweepResult,
                         as_spec, as_trace, run, sweep)
from .metrics import PolicyPoint, evaluate, normalize_waste, pareto_frontier

__all__ = [
    "policy_math",
    "AppHistogram", "HistogramConfig", "HistogramState", "init_state",
    "FixedKeepAlivePolicy", "HybridConfig", "HybridHistogramPolicy",
    "NoUnloadingPolicy", "Policy", "PolicyWindows", "is_warm",
    "loaded_idle_time", "SimResult", "simulate_scalar",
    "ENGINES", "EngineOptions", "FixedSpec", "HybridSpec", "NoUnloadSpec",
    "PolicySpec", "SweepGrid", "SweepResult", "as_spec", "as_trace", "run",
    "sweep",
    "AppSpec", "Trace", "generate_trace", "sample_apps",
    "SCENARIOS", "Cohort", "WorkloadSpec", "azure_like", "bursty", "diurnal",
    "flash_crowd", "scenario", "timer_heavy", "weekend_dip",
    "PolicyPoint", "evaluate", "normalize_waste", "pareto_frontier",
]

_REMOVED_SIMULATE = ("simulate", "simulate_fixed_batch",
                     "simulate_hybrid_batch",
                     "simulate_hybrid_batch_reference")


def __getattr__(name: str):
    if name in _REMOVED_SIMULATE:
        # Defer to the simulator module's message (points at experiment.run).
        from . import simulator
        return getattr(simulator, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
