"""Core: the paper's contribution — hybrid histogram cold-start policy.

All decision formulas are single-sourced in :mod:`repro.core.policy_math`;
everything else (scalar policy, batched engines, Pallas kernels, serving
warm pool) is representation-specific glue around those helpers.
"""
from . import policy_math
from .histogram import AppHistogram, HistogramConfig, HistogramState, init_state
from .policy import (FixedKeepAlivePolicy, HybridConfig, HybridHistogramPolicy,
                     NoUnloadingPolicy, Policy, PolicyWindows, is_warm,
                     loaded_idle_time)
from .simulator import (SimResult, simulate, simulate_fixed_batch,
                        simulate_hybrid_batch, simulate_hybrid_batch_reference,
                        simulate_scalar)
from .experiment import (ENGINES, EngineOptions, FixedSpec, HybridSpec,
                         NoUnloadSpec, PolicySpec, SweepResult, as_spec, run,
                         sweep)
from .workload import AppSpec, Trace, generate_trace, sample_apps
from .metrics import PolicyPoint, evaluate, normalize_waste, pareto_frontier

__all__ = [
    "policy_math",
    "AppHistogram", "HistogramConfig", "HistogramState", "init_state",
    "FixedKeepAlivePolicy", "HybridConfig", "HybridHistogramPolicy",
    "NoUnloadingPolicy", "Policy", "PolicyWindows", "is_warm",
    "loaded_idle_time", "SimResult", "simulate", "simulate_fixed_batch",
    "simulate_hybrid_batch", "simulate_hybrid_batch_reference",
    "simulate_scalar",
    "ENGINES", "EngineOptions", "FixedSpec", "HybridSpec", "NoUnloadSpec",
    "PolicySpec", "SweepResult", "as_spec", "run", "sweep",
    "AppSpec", "Trace",
    "generate_trace", "sample_apps", "PolicyPoint", "evaluate",
    "normalize_waste", "pareto_frontier",
]
